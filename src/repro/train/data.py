"""Bucketed training batches over stage-1 candidate sequences.

The engine pads query *batches* to power-of-two sizes so jit compiles once
per bucket (engine/server.bucket_size); training reuses the same idiom on
the *sequence* axis. Most queries' useful supervision lives in a short
prefix of the n-candidate sequence — trailing candidates have zero sparse
overlap and no positive label — so each query gets an effective length
(last live candidate), is bucketed to the next power of two, and training
steps compile once per bucket length instead of always scanning all n
steps. Truncation is exact for every selector the repo ships (LSTM/RNN
scans are causal, the MLP is pointwise): probabilities over the kept
prefix are bitwise those of the full-length run.

Batches are fixed (batch_size, L, F) shapes — short tails are padded by
repeating rows with weight 0, so every (bucket, batch_size) pair compiles
exactly once and padding never contributes loss. The batch stream is a
pure function of (seed, epoch, buckets): mid-epoch checkpoint resume
replays the identical schedule (tests/test_train.py pins this).
"""

import dataclasses

import numpy as np

from repro.engine.server import bucket_size


@dataclasses.dataclass
class Batch:
    feats: np.ndarray     # (batch_size, L, F) float32
    labels: np.ndarray    # (batch_size, L) float32
    weights: np.ndarray   # (batch_size,) float32 — 0 marks padding rows
    length: int           # bucket (sequence) length L
    index: int            # step index within the epoch


def effective_lengths(cfg, feats, labels, *, min_len=4):
    """Per-query live prefix: covers every candidate with nonzero sparse
    overlap (the P/Q feature block) AND every positive label, so no
    supervision signal is dropped by truncation."""
    feats = np.asarray(feats)
    labels = np.asarray(labels)
    n = feats.shape[1]
    overlap = np.abs(feats[..., 1 + cfg.u_bins:]).sum(axis=-1) > 0
    live = overlap | (labels > 0)
    any_live = live.any(axis=1)
    last = np.where(any_live, n - 1 - np.argmax(live[:, ::-1], axis=1), 0)
    return np.clip(last + 1, min(min_len, n), n).astype(np.int64)


def bucket_lengths(cfg, feats, labels, *, min_len=4):
    """Effective lengths rounded up to the engine's power-of-two buckets,
    capped at the full candidate length n."""
    n = int(np.asarray(feats).shape[1])
    eff = effective_lengths(cfg, feats, labels, min_len=min_len)
    return np.asarray([bucket_size(int(e), n) for e in eff], np.int64)


def n_batches_per_epoch(buckets, batch_size):
    lens, counts = np.unique(np.asarray(buckets), return_counts=True)
    return int(sum(-(-int(c) // int(batch_size)) for c in counts))


def bucketed_batches(feats, labels, buckets, *, batch_size, seed, epoch):
    """Yield one epoch of Batch objects, deterministic in (seed, epoch).

    Queries are shuffled *within* their bucket; buckets are visited in
    ascending length order. Every query appears exactly once per epoch;
    tail batches are padded to batch_size by repeating the final row with
    weight 0."""
    feats = np.asarray(feats)
    labels = np.asarray(labels)
    buckets = np.asarray(buckets)
    batch_size = max(1, int(batch_size))
    rng = np.random.default_rng([int(seed), int(epoch)])
    step = 0
    for L in sorted(int(x) for x in np.unique(buckets)):
        idx = np.flatnonzero(buckets == L)
        idx = rng.permutation(idx)
        for lo in range(0, len(idx), batch_size):
            sel = idx[lo:lo + batch_size]
            pad = batch_size - len(sel)
            w = np.ones(batch_size, np.float32)
            if pad:
                sel = np.concatenate([sel, np.repeat(sel[-1:], pad)])
                w[len(w) - pad:] = 0.0
            yield Batch(feats=feats[sel][:, :L],
                        labels=labels[sel][:, :L],
                        weights=w, length=L, index=step)
            step += 1
