"""Selector supervision at corpus scale (paper §2.3): a candidate cluster
is POSITIVE iff it holds at least one of the query's top-`top_dense` *full
dense retrieval* results.

Two label paths produce bit-identical `(cand, feats, labels)`:

  * `make_labels(cfg, index, ...)` — the seed-era in-RAM path:
    `full_dense_topk` over a materialized `index.embeddings` matrix. Kept
    for small corpora and as the parity oracle.
  * `make_labels_streaming(cfg, index, store, ...)` — the exact same
    supervision computed against a *built on-disk index*: the full-dense
    top-k is an exact running merge over cluster blocks streamed through
    any host `ClusterStore` backend (`ShardedDiskStore`, `ShardedPQStore`,
    memmap-backed `DiskStore`), at most `chunk_clusters` blocks per fetch.
    The embedding matrix is never materialized; peak resident rows are
    `chunk_clusters * cap`.

Exactness: per-chunk scores are the same jnp matmul as `full_dense_topk`
restricted to the chunk's columns (bitwise-equal on a fixed backend), and
the running merge ranks by (score desc, doc id asc) — `jax.lax.top_k`'s
tie rule, since the full-matrix column index IS the doc id. For a v2 (PQ)
index the streamed blocks are decode-on-fetch reconstructions, so the
labels match the in-RAM path run on the decoded matrix — i.e. supervision
is exact w.r.t. what the index actually stores and serves.

Generated labels can be spilled to a reusable on-disk `LabelCache` keyed
by index generation + artifact checksums + label config + query
fingerprint, so calibration sweeps and repeated training runs never redo
the streaming pass.
"""

import dataclasses
import hashlib
import json
import os
import time
from typing import Any, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import clusd as clusd_lib
from repro.core import sparse as sparse_lib

_PAD_ID = np.int64(1) << 62      # sorts after every real doc id on ties


@dataclasses.dataclass(frozen=True)
class LabelConfig:
    """What a label set depends on (besides the index + query set)."""

    top_dense: int = 10          # paper: top-10 full dense results
    stage1: str = "overlap"      # stage-1 candidate ordering
    chunk_clusters: int = 64     # cluster blocks per streamed fetch
    use_kernel: bool = False     # route chunk scoring via cluster_score


@dataclasses.dataclass
class LabelGenStats:
    n_fetches: int = 0
    blocks_read: int = 0
    bytes_read: int = 0
    stream_wall_s: float = 0.0   # fetch + score + merge time only
    wall_s: float = 0.0          # whole label pass incl. stage-1 features

    def add(self, n_blocks, n_bytes, wall_s):
        self.n_fetches += 1
        self.blocks_read += int(n_blocks)
        self.bytes_read += int(n_bytes)
        self.stream_wall_s += float(wall_s)


@dataclasses.dataclass
class LabelSet:
    """One query set's supervision: stage-1 candidates + features and the
    positive/negative label per candidate, plus the full-dense top-k ids
    the labels were derived from (reused by calibration's recall@budget)."""

    cand: np.ndarray         # (B, n) int32 stage-1 candidate cluster ids
    feats: np.ndarray        # (B, n, F) float32 LSTM input features
    labels: np.ndarray       # (B, n) float32 in {0, 1}
    dense_ids: np.ndarray    # (B, top_dense) int32 full-dense top-k doc ids
    stats: Optional[LabelGenStats] = None

    @property
    def n_queries(self):
        return int(self.cand.shape[0])

    @property
    def pos_rate(self):
        return float(np.asarray(self.labels).mean())


# ---------------------------------------------------------------------------
# in-RAM path (seed behavior, unchanged — also the parity oracle)
# ---------------------------------------------------------------------------

def make_labels(cfg, index, q_dense, q_terms, q_weights, top_dense=10,
                stage1="overlap"):
    """Returns (cand (B, n), feats (B, n, F), labels (B, n)).

    Requires a materialized `index.embeddings` matrix; for built on-disk
    indexes use `make_labels_streaming` (same outputs, bounded reads)."""
    cand, feats, sparse_ids, sparse_scores = _stage1(
        cfg, index, q_dense, q_terms, q_weights, stage1)
    dense_ids, _ = clusd_lib.full_dense_topk(index.embeddings, q_dense,
                                             top_dense)
    labels = _labels_from_dense(index, cand, dense_ids)
    return cand, feats, labels


def _stage1(cfg, index, q_dense, q_terms, q_weights, stage1):
    sparse_ids, sparse_scores = sparse_lib.sparse_retrieve_topk(
        index.sparse_index, q_terms, q_weights, cfg.k_sparse)
    s1 = clusd_lib.stage1_candidates(cfg, index, q_dense, sparse_ids,
                                     sparse_scores, stage1=stage1)
    return s1["cand"], s1["feats"], sparse_ids, sparse_scores


def stage1_for_queries(cfg, index, q_dense, q_terms, q_weights,
                       stage1="overlap"):
    """Stage-1 candidates + features for a query set, as host arrays.

    Public wrapper for calibration's expansion sweep: re-running stage 1
    at a different `cfg.expand_depth` only changes (cand, feats) — the
    full-dense ids in an existing LabelSet stay valid, so the sweep never
    re-streams the corpus."""
    cand, feats, _, _ = _stage1(cfg, index, q_dense, q_terms, q_weights,
                                stage1)
    return np.asarray(cand), np.asarray(feats)


def relabel_for_config(cfg, index, q_dense, q_terms, q_weights, dense_ids, *,
                       stage1="overlap") -> LabelSet:
    """Rebuild a LabelSet for a new candidate-generation config (e.g. a
    different `expand_depth`) from an existing full-dense top-k. The
    expensive streamed dense pass is stage-1-independent, so retraining
    the selector on expanded candidate sequences costs only a stage-1
    re-run."""
    cand, feats, _, _ = _stage1(cfg, index, q_dense, q_terms, q_weights,
                                stage1)
    dense_ids = np.asarray(dense_ids)
    labels = _labels_from_dense(index, cand, jnp.asarray(dense_ids))
    return LabelSet(cand=np.asarray(cand), feats=np.asarray(feats),
                    labels=np.asarray(labels), dense_ids=dense_ids)


def _labels_from_dense(index, cand, dense_ids):
    pos_clusters = jnp.take(index.doc_cluster, dense_ids, axis=0)  # (B, k)
    labels = jnp.any(cand[:, :, None] == pos_clusters[:, None, :], axis=-1)
    return labels.astype(jnp.float32)


# ---------------------------------------------------------------------------
# streaming full-dense top-k over a ClusterStore
# ---------------------------------------------------------------------------

def _chunk_scores(q_dense, vecs, use_kernel):
    """(B, dim) x (U, cap, dim) -> (B, U*cap) float32 dot scores."""
    U, cap, dim = vecs.shape
    if use_kernel:
        from repro.kernels.cluster_score import cluster_score
        B = q_dense.shape[0]
        sel = jnp.broadcast_to(jnp.arange(U, dtype=jnp.int32)[None, :],
                               (B, U))
        return np.asarray(cluster_score(jnp.asarray(q_dense),
                                        jnp.asarray(vecs),
                                        sel)).reshape(B, U * cap)
    # same matmul as full_dense_topk restricted to this chunk's columns —
    # bitwise-equal scores on a fixed backend (the parity contract)
    flat = jnp.asarray(np.ascontiguousarray(vecs).reshape(U * cap, dim))
    return np.asarray(jnp.asarray(q_dense) @ flat.T)


def _merge_topk(best_s, best_i, new_s, new_i, k):
    """Running (score desc, id asc) top-k merge — lax.top_k's tie rule."""
    s = np.concatenate([best_s, new_s], axis=1)
    i = np.concatenate([best_i, new_i], axis=1)
    order = np.lexsort((i, -s), axis=-1)[:, :k]
    return (np.take_along_axis(s, order, axis=1),
            np.take_along_axis(i, order, axis=1))


def streaming_full_dense_topk(store, q_dense, k, *, chunk_clusters=64,
                              use_kernel=False, stats: LabelGenStats = None):
    """Exact full-dense top-k computed by streaming cluster blocks.

    Every `fetch_blocks` call asks for at most `chunk_clusters` cluster
    ids (bounded-read contract, enforced by tests/test_train.py); a
    running per-query top-k merge keeps only (B, k) candidates resident.
    Returns (ids (B, k) int32, scores (B, k) f32), identical to
    `full_dense_topk(embeddings, q_dense, k)` over the matrix the store
    decodes to (exact floats for v1 blocks, PQ reconstructions for v2).
    """
    q = np.asarray(q_dense)
    B = q.shape[0]
    N = int(store.cluster_docs.shape[0])
    chunk_clusters = max(1, int(chunk_clusters))
    best_s = np.full((B, k), -np.inf, np.float32)
    best_i = np.full((B, k), _PAD_ID, np.int64)
    block_bytes = int(getattr(store, "block_bytes", 0))
    for lo in range(0, N, chunk_clusters):
        ids = np.arange(lo, min(lo + chunk_clusters, N), dtype=np.int64)
        t0 = time.perf_counter()
        vecs, docs, valid = store.fetch_blocks(ids)
        vecs = np.asarray(vecs)
        docs = np.asarray(docs)
        valid = np.asarray(valid)
        scores = _chunk_scores(q, vecs, use_kernel)          # (B, U*cap)
        flat_docs = docs.reshape(-1).astype(np.int64)
        flat_valid = valid.reshape(-1)
        # mask padded / tombstoned slots out of the merge entirely
        scores = np.where(flat_valid[None, :], scores, -np.inf)
        ids_row = np.where(flat_valid, flat_docs, _PAD_ID)
        best_s, best_i = _merge_topk(
            best_s, best_i, scores.astype(np.float32),
            np.broadcast_to(ids_row[None, :], scores.shape), k)
        if stats is not None:
            stats.add(len(ids), len(ids) * block_bytes,
                      time.perf_counter() - t0)
    if np.any(best_i >= _PAD_ID):
        raise ValueError(f"corpus holds fewer than k={k} live documents")
    return best_i.astype(np.int32), best_s


def make_labels_streaming(cfg, index, store, q_dense, q_terms, q_weights, *,
                          label_cfg: LabelConfig = LabelConfig(),
                          metrics=None):
    """Index-backed `make_labels`: identical `(cand, feats, labels)` with
    the full-dense pass streamed through `store` (bounded reads, no
    materialized embedding matrix). Returns a LabelSet. `metrics`
    (repro.obs.MetricsRegistry) gets the pass recorded under `labels.*`."""
    stats = LabelGenStats()
    t0 = time.perf_counter()
    cand, feats, _, _ = _stage1(cfg, index, q_dense, q_terms, q_weights,
                                label_cfg.stage1)
    dense_ids, _ = streaming_full_dense_topk(
        store, q_dense, label_cfg.top_dense,
        chunk_clusters=label_cfg.chunk_clusters,
        use_kernel=label_cfg.use_kernel, stats=stats)
    labels = _labels_from_dense(index, cand, jnp.asarray(dense_ids))
    stats.wall_s = time.perf_counter() - t0
    ls = LabelSet(cand=np.asarray(cand), feats=np.asarray(feats),
                  labels=np.asarray(labels), dense_ids=dense_ids,
                  stats=stats)
    if metrics is not None:
        record_label_metrics(metrics, ls)
    return ls


def record_label_metrics(registry, ls: LabelSet):
    """Fold one label pass into `labels.*` metrics: fetch/byte counters
    (cumulative across passes) and a queries-per-second gauge for the
    most recent pass."""
    st = ls.stats
    if st is None:
        return
    registry.counter("labels.passes").inc()
    registry.counter("labels.queries").inc(ls.n_queries)
    registry.counter("labels.n_fetches").inc(st.n_fetches)
    registry.counter("labels.blocks_read").inc(st.blocks_read)
    registry.counter("labels.bytes_read").inc(st.bytes_read)
    registry.counter("labels.stream_ms").inc(round(st.stream_wall_s * 1e3, 3))
    registry.counter("labels.wall_ms").inc(round(st.wall_s * 1e3, 3))
    if st.wall_s > 0:
        registry.gauge("labels.queries_per_s").set(
            round(ls.n_queries / st.wall_s, 2))


# ---------------------------------------------------------------------------
# reusable on-disk label cache
# ---------------------------------------------------------------------------

def query_fingerprint(q_dense, q_terms, q_weights):
    h = hashlib.sha256()
    for a in (q_dense, q_terms, q_weights):
        a = np.ascontiguousarray(np.asarray(a))
        h.update(str(a.dtype).encode())
        h.update(str(a.shape).encode())
        h.update(a.tobytes())
    return h.hexdigest()


# config fields the labels actually depend on: sparse retrieval + stage-1
# candidate ordering + features. Selector-side fields (theta, max_selected,
# pos_weight, lr, ...) deliberately excluded — a selector publish bumps the
# index generation without touching the corpus, and must not invalidate
# cached labels.
_LABEL_CFG_FIELDS = ("n_docs", "dim", "n_clusters", "vocab", "max_postings",
                     "k_sparse", "bins", "n_candidates", "n_neighbors",
                     "u_bins", "expand_depth")


def label_cache_key(manifest, cfg, label_cfg: LabelConfig, q_fingerprint):
    """Cache key: per-artifact content hashes (every non-selector file —
    any corpus delta rewrites arrays/shards, so their sha256s pin the
    exact documents) + the label-relevant config + label config + the
    query-set fingerprint. Selector-only generations (publishes) reuse
    the cache; over-keying is safe, staleness is not."""
    ident = {
        "format_version": manifest["format_version"],
        "geometry": manifest["geometry"],
        "files": {rel: e["sha256"]
                  for rel, e in (manifest.get("files") or {}).items()
                  if not rel.startswith("lstm")},   # selector never feeds labels
        "config": {f: getattr(cfg, f) for f in _LABEL_CFG_FIELDS},
        "label_config": dataclasses.asdict(label_cfg),
        "queries": q_fingerprint,
    }
    blob = json.dumps(ident, sort_keys=True, default=str).encode()
    return hashlib.sha256(blob).hexdigest()


class LabelCache:
    """Directory of spilled LabelSets, one `<key>.npz` + `<key>.json` pair
    per (index generation, label config, query set). Writes are atomic
    (tmp + os.replace), so a crashed run never leaves a torn entry."""

    def __init__(self, cache_dir):
        self.dir = os.path.abspath(cache_dir)
        os.makedirs(self.dir, exist_ok=True)

    def _paths(self, key):
        return (os.path.join(self.dir, f"{key}.npz"),
                os.path.join(self.dir, f"{key}.json"))

    def load(self, key) -> Optional[LabelSet]:
        npz, meta = self._paths(key)
        if not (os.path.isfile(npz) and os.path.isfile(meta)):
            return None
        with np.load(npz) as z:
            return LabelSet(cand=z["cand"], feats=z["feats"],
                            labels=z["labels"], dense_ids=z["dense_ids"])

    def save(self, key, ls: LabelSet, extra: Any = None):
        npz, meta = self._paths(key)
        tmp = npz + ".tmp"
        with open(tmp, "wb") as f:      # file handle: savez must not append
            np.savez(f, cand=ls.cand, feats=ls.feats, labels=ls.labels,
                     dense_ids=ls.dense_ids)     # .npz to the tmp name
        os.replace(tmp, npz)
        info = {"n_queries": ls.n_queries, "pos_rate": ls.pos_rate,
                "extra": extra or {}}
        if ls.stats is not None:
            info["gen_stats"] = dataclasses.asdict(ls.stats)
        tmp = meta + ".tmp"
        with open(tmp, "w") as f:
            json.dump(info, f, indent=1, sort_keys=True)
        os.replace(tmp, meta)
        return npz

    def get_or_build(self, key, build_fn, extra=None, metrics=None):
        """Returns (LabelSet, cache_hit). `metrics` counts the outcome
        under `labels.cache_hits` / `labels.cache_misses`."""
        ls = self.load(key)
        if ls is not None:
            if metrics is not None:
                metrics.counter("labels.cache_hits").inc()
            return ls, True
        ls = build_fn()
        self.save(key, ls, extra=extra)
        if metrics is not None:
            metrics.counter("labels.cache_misses").inc()
        return ls, False
