"""Atomic selector publishing: commit trained weights + calibrated
thresholds into a built index as a new generation.

Reuses the PR-4 generation protocol (repro.index.update): new artifacts
are staged under `<index_dir>/.stage-g<G>` with generation-suffixed names
(`lstm.g<G>/step_0/...`), moved into place without clobbering anything
the live manifest references, the current manifest is archived to
`manifests/manifest.g<g>.json`, and the new manifest atomically replaces
`manifest.json`. A reader racing the commit sees either generation, never
a torn index; a serving engine adopts the new selector between batches
via `RetrievalEngine.reload_selector()` (or a full `reload_index()`) with
no failed requests.

What a publish changes in the manifest:

  generation / parent_generation   bumped / set to the previous generation
  lstm                             points at the new `lstm.g<G>` checkpoint
  config.theta / config.max_selected   the calibrated operating point —
                                   readers serve it with no extra wiring
  selector                         metadata block: operating point, the
                                   full calibration table, label config,
                                   and training stats (see format.py)

Cluster blocks, arrays, and postings are carried by reference — a publish
rewrites zero corpus bytes. `compact_index` keeps the weights and the
calibrated config (it serializes what the reader loads) but drops the
auxiliary `selector` metadata block, like any non-layout bookkeeping.
"""

import copy
import os
import shutil
import time

import numpy as np

from repro.checkpoint import save_checkpoint
from repro.index import format as fmt


def _stage_relpaths(stage):
    out = []
    for dirpath, _, names in os.walk(stage):
        for name in sorted(names):
            out.append(os.path.relpath(os.path.join(dirpath, name), stage))
    return sorted(out)


def publish_selector(index_dir, params, *, theta=None, budget=None,
                     calibration=None, label_config=None, train_meta=None,
                     selector="lstm", verify="size", expand_depth=None,
                     fusion=None):
    """Commit `params` (+ calibrated theta/budget) to the index at
    `index_dir` as generation G = current + 1. Returns a report dict.

    A hybrid calibration may also retune candidate generation:
    `expand_depth` (stage-1 neighbor-graph expansion) and `fusion`
    ("interp" | "rrf") land in the manifest config the same way
    theta/budget do — readers serve them with no extra wiring, and
    `RetrievalEngine.reload_selector()` recompiles its Stage-I buckets
    when the expansion changed.

    Only the paper's LSTM selector round-trips through the manifest's
    `lstm` checkpoint schema; other selector kinds must extend it first.
    """
    if selector != "lstm":
        raise ValueError(f"publish supports the lstm selector (manifest "
                         f"schema), got {selector!r}")
    t0 = time.perf_counter()
    manifest = fmt.load_manifest(index_dir)
    fmt.verify_files(index_dir, manifest, level=verify)
    g = fmt.manifest_generation(manifest)
    G = g + 1

    host = {k: np.asarray(v) for k, v in params.items()}
    for key in ("wx", "wh", "b", "head_w", "head_b"):
        if key not in host:
            raise ValueError(f"lstm params missing leaf {key!r}")
    feat_dim = int(host["wx"].shape[0])
    hidden = int(host["wh"].shape[0])

    # -- stage the new checkpoint under a generation-suffixed dir ----------
    stage = os.path.join(index_dir, f".stage-g{G}")
    if os.path.exists(stage):
        shutil.rmtree(stage)
    os.makedirs(stage)
    lstm_dir = f"lstm.g{G}"
    lstm_meta = {"dir": lstm_dir, "step": 0, "selector": selector,
                 "feat_dim": feat_dim, "hidden": hidden}
    save_checkpoint(os.path.join(stage, lstm_dir), 0, host,
                    extra={k: lstm_meta[k]
                           for k in ("selector", "feat_dim", "hidden")})
    staged = _stage_relpaths(stage)

    # -- manifest for generation G -----------------------------------------
    new_manifest = copy.deepcopy(manifest)
    new_manifest["generation"] = G
    new_manifest["parent_generation"] = g
    new_manifest["lstm"] = lstm_meta
    cfg_d = new_manifest["config"]
    if theta is not None:
        cfg_d["theta"] = float(theta)
    if budget is not None:
        cfg_d["max_selected"] = int(budget)
    if expand_depth is not None:
        cfg_d["expand_depth"] = int(expand_depth)
    if fusion is not None:
        from repro.core.fusion import FUSION_METHODS
        if fusion not in FUSION_METHODS:
            raise ValueError(f"fusion must be one of {FUSION_METHODS}, "
                             f"got {fusion!r}")
        cfg_d["fusion"] = str(fusion)
    new_manifest["selector"] = {
        "selector": selector,
        "published_generation": G,
        "theta": cfg_d["theta"],
        "budget": cfg_d["max_selected"],
        "expand_depth": int(cfg_d.get("expand_depth", 0)),
        "fusion": str(cfg_d.get("fusion", "interp")),
        "calibration": list(calibration or []),
        "label_config": dict(label_config or {}),
        "train": dict(train_meta or {}),
    }

    old_lstm = (manifest.get("lstm") or {}).get("dir")
    files = {rel: e for rel, e in manifest["files"].items()
             if not (old_lstm and (rel == old_lstm
                                   or rel.startswith(old_lstm + "/")
                                   or rel.startswith(old_lstm + os.sep)))}
    for rel in staged:
        full = os.path.join(stage, rel)
        files[rel] = {"bytes": os.path.getsize(full),
                      "sha256": fmt.file_sha256(full)}
    new_manifest["files"] = files
    new_manifest["total_bytes"] = sum(e["bytes"] for e in files.values())

    # -- commit: the shared generation protocol (index/format.py) ----------
    fmt.commit_generation(index_dir, stage, staged, manifest, new_manifest)

    return {
        "generation": G,
        "parent_generation": g,
        "lstm_dir": lstm_dir,
        "theta": cfg_d["theta"],
        "budget": cfg_d["max_selected"],
        "n_files_added": len(staged),
        "bytes_added": sum(files[rel]["bytes"] for rel in staged),
        "wall_s": round(time.perf_counter() - t0, 3),
    }
