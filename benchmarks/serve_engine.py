"""Serving-layer benchmark for the unified RetrievalEngine: latency
percentiles + QPS through bucketed batching (in-memory backend), I/O
accounting for the on-disk backend (batch-dedup + LRU cache + Stage-I
prefetch) vs the seed per-query read loop (one block read per
(query, selected cluster) pair), and the format-v2 PQ code-shard backend —
same engine, 4*dim/nsub fewer bytes off disk, MRR@10 within 0.02 of the
float32 in-memory backend (asserted).

Writes BENCH_serve.json at the repo root so later PRs have a perf
trajectory to beat. Standalone: PYTHONPATH=src python -m benchmarks.serve_engine
"""

import dataclasses
import json
import os
import tempfile
import time

import jax
import numpy as np

from benchmarks import common as C
from repro.core import clusd as cl
from repro.core import disk as dk
from repro.core import train_lstm as tl
from repro.data import mrr_at, synth_corpus, synth_queries
from repro.engine import DiskStore, RetrievalEngine

N_DOCS = 20_000          # acceptance corpus size (fixed, not BENCH_SCALE-d)
N_QUERIES = 256
MAX_BATCH = 32
# ragged request sizes: exercises pad-to-power-of-two bucketing (32 and 16)
BATCH_CYCLE = (32, 24, 12)


def _serve(engine, qs, n, cycle):
    i, sizes = 0, []
    ids = []
    t0 = time.perf_counter()
    while i < n:
        b = cycle[len(sizes) % len(cycle)]
        b = min(b, n - i)
        out, _ = engine.retrieve(qs.q_dense[i:i + b], qs.q_terms[i:i + b],
                                 qs.q_weights[i:i + b])
        ids.append(np.asarray(out))
        sizes.append(b)
        i += b
    wall = time.perf_counter() - t0
    return np.concatenate(ids), sizes, wall


def run():
    cfg = dataclasses.replace(C.bench_cfg(), n_docs=N_DOCS,
                              train_queries=512, epochs=25)
    corpus = synth_corpus(0, cfg.n_docs, cfg.dim, cfg.vocab, topic_noise=0.5)
    index = cl.build_index(cfg, jax.random.key(0), corpus.embeddings,
                           corpus.doc_terms, corpus.doc_weights)
    tq = synth_queries(1, corpus, cfg.train_queries)
    _, feats, labels = tl.make_labels(cfg, index, tq.q_dense, tq.q_terms,
                                      tq.q_weights)
    index.lstm_params, _ = tl.train_selector(cfg, jax.random.key(2),
                                             np.asarray(feats),
                                             np.asarray(labels))
    qs = synth_queries(9, corpus, N_QUERIES, dense_noise=0.30,
                       term_noise_frac=0.4)
    rows = []

    # ---- in-memory backend: bucketed batching --------------------------
    engine = RetrievalEngine(cfg, index, max_batch=MAX_BATCH)
    ids, sizes, wall = _serve(engine, qs, N_QUERIES, BATCH_CYCLE)
    st = engine.stats()
    mem_row = {
        "backend": "in-memory",
        "MRR@10": round(mrr_at(ids, qs.rel_doc), 4),
        # p50/p99 are steady-state (jit-compile batches excluded)
        "p50_batch_ms": st["p50_ms"], "p99_batch_ms": st["p99_ms"],
        "qps_total": round(N_QUERIES / wall, 1),
        "qps_steady": st["qps_steady"],
        "compiled_buckets": st["compiled_buckets"],
        "n_batches": st["n_batches"],
    }
    rows.append(mem_row)

    # ---- seed-equivalent on-disk op count ------------------------------
    # the pre-engine per-query loop read one block per (query, selected
    # cluster); that count is sum(sel_mask) over the query set.
    _, _, diag = cl.retrieve(cfg, index, qs.q_dense, qs.q_terms, qs.q_weights)
    seed_ops = int(np.asarray(diag["sel_mask"]).sum())

    # ---- on-disk backend: dedup + LRU cache + prefetch -----------------
    tmp = tempfile.mkdtemp()
    blocks = dk.DiskClusterStore(os.path.join(tmp, "blocks.bin"),
                                 corpus.embeddings, index.cluster_docs)
    with RetrievalEngine(cfg, index,
                         store=DiskStore(blocks, index.cluster_docs),
                         max_batch=MAX_BATCH,
                         cache_capacity=cfg.n_clusters) as deng:
        ids_d, _, wall_d = _serve(deng, qs, N_QUERIES, (MAX_BATCH,))
    # stats after close(): prefetch worker drained, I/O counters final
    ds = deng.stats()
    io, cache = ds["io"], ds["cache"]
    disk_row = {
        "backend": "on-disk (engine)",
        "MRR@10": round(mrr_at(ids_d, qs.rel_doc), 4),
        "p50_batch_ms": ds["p50_ms"], "p99_batch_ms": ds["p99_ms"],
        "qps_total": round(N_QUERIES / wall_d, 1),
        "qps_steady": ds["qps_steady"],
        "block_read_ops": io["n_ops"],
        "seed_equiv_ops": seed_ops,
        "io_op_reduction": round(seed_ops / max(io["n_ops"], 1), 2),
        "bytes_read": io["bytes"],
        "mb_read": round(io["bytes"] / 2**20, 2),
        "io_model_ms": io["model_ms"],
        "cache_hit_rate": cache["hit_rate"],
        "prefetch_enqueued": ds["prefetch_enqueued"],
    }
    rows.append(disk_row)
    assert io["n_ops"] < seed_ops, \
        f"engine read {io['n_ops']} blocks, seed loop would read {seed_ops}"

    # ---- format-v2 PQ code shards through the same engine ---------------
    from repro import index as index_lib
    from repro.core import quant as quant_lib
    index.quantizer = quant_lib.train_pq(jax.random.key(3),
                                         corpus.embeddings, 12, rotate=True)
    pq_dir = os.path.join(tmp, "index_pq")
    emb = np.asarray(corpus.embeddings)
    index_lib.write_index(pq_dir, cfg, index, emb, n_shards=8,
                          format_version=index_lib.FORMAT_VERSION_PQ)
    index.quantizer = None
    reader = index_lib.IndexReader.open(pq_dir, verify="size")
    with reader.engine(max_batch=MAX_BATCH,
                       cache_capacity=cfg.n_clusters) as peng:
        ids_p, _, wall_p = _serve(peng, qs, N_QUERIES, (MAX_BATCH,))
    ps = peng.stats()
    pio, pcache = ps["io"], ps["cache"]
    mrr_pq = round(mrr_at(ids_p, qs.rel_doc), 4)
    pq_row = {
        "backend": "pq-sharded (v2 index)",
        "MRR@10": mrr_pq,
        "mrr_delta_vs_inmemory": round(abs(mrr_pq - mem_row["MRR@10"]), 4),
        "p50_batch_ms": ps["p50_ms"], "p99_batch_ms": ps["p99_ms"],
        "qps_total": round(N_QUERIES / wall_p, 1),
        "qps_steady": ps["qps_steady"],
        "block_read_ops": pio["n_ops"],
        "bytes_read": pio["bytes"],
        "mb_read": round(pio["bytes"] / 2**20, 2),
        "code_byte_reduction": round(io["bytes"] / max(pio["bytes"], 1), 1),
        "cache_hit_rate": pcache["hit_rate"],
    }
    rows.append(pq_row)
    assert pq_row["mrr_delta_vs_inmemory"] <= 0.02, \
        f"PQ serving MRR {mrr_pq} vs in-memory {mem_row['MRR@10']}"

    result = {"table": "serve_engine", "n_docs": N_DOCS,
              "n_queries": N_QUERIES, **C.bench_meta(cfg), "rows": rows}
    out = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "BENCH_serve.json"))
    with open(out, "w") as f:
        json.dump(result, f, indent=1)
    print(f"wrote {out}")
    return result


if __name__ == "__main__":
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    res = run()
    for r in res["rows"]:
        print(json.dumps(r))
