"""Serving-layer benchmark for the unified RetrievalEngine: latency
percentiles + QPS through bucketed batching (in-memory backend), I/O
accounting for the on-disk backend (batch-dedup + LRU cache + Stage-I
prefetch) vs the seed per-query read loop (one block read per
(query, selected cluster) pair), the format-v2 PQ code-shard backend
served via in-kernel ADC (raw codes -> LUT scoring inside the fused
score->fuse->top-k tail; zero host decode), and the reduced-precision v1
shard dtypes (bfloat16, int8).

Asserted invariants: every lossy backend stays within 0.02 MRR@10 of the
float32 in-memory backend; the ADC path's MRR is IDENTICAL to the
decode-then-score path over the same v2 index; and the pq-sharded p50
batch latency beats the in-memory p50 (the point of the ADC+fused-tail
serving path). A cache-budget sweep records the hit-rate gain from
caching codes instead of float blocks at the same byte budget.

The pq-sharded engine additionally runs an untraced and a fully traced
steady pass (repro.obs stage-span tracing) to emit `stage_breakdown_ms`
— per-stage totals whose depth-1 spans must cover >=90% of the traced
batch wall time — and a `trace_overhead` pair; check_regression.py gates
the traced p50 at 1.05x the untraced p50.

A `router_scaling` section runs the multi-host scatter-gather ShardRouter
over the same v2 index at 1/2/3 hosts with a simulated per-host I/O
service time (the box is one core, so scaling comes from overlapping the
simulated remote fetches, not from compute): check_regression.py gates
3-host QPS at >=1.8x 1-host. A failover row kills one of three hosts
(replication 2) and must serve every request exactly (bitwise id parity
with the single-host engine, zero failed/degraded).

Writes BENCH_serve.json at the repo root so later PRs have a perf
trajectory to beat. Standalone: PYTHONPATH=src python -m benchmarks.serve_engine
"""

import dataclasses
import json
import os
import tempfile
import time

import jax
import numpy as np

from benchmarks import common as C
from repro.core import clusd as cl
from repro.core import disk as dk
from repro.core import train_lstm as tl
from repro.data import mrr_at, synth_corpus, synth_queries
from repro.engine import DiskStore, RetrievalEngine

N_DOCS = 20_000          # acceptance corpus size (fixed, not BENCH_SCALE-d)
N_QUERIES = 256
MAX_BATCH = 32
# ragged request sizes: exercises pad-to-power-of-two bucketing (32 and 16)
BATCH_CYCLE = (32, 24, 12)


def _serve(engine, qs, n, cycle):
    i, sizes = 0, []
    ids = []
    t0 = time.perf_counter()
    while i < n:
        b = cycle[len(sizes) % len(cycle)]
        b = min(b, n - i)
        out, _ = engine.retrieve(qs.q_dense[i:i + b], qs.q_terms[i:i + b],
                                 qs.q_weights[i:i + b])
        ids.append(np.asarray(out))
        sizes.append(b)
        i += b
    wall = time.perf_counter() - t0
    return np.concatenate(ids), sizes, wall


def run():
    cfg = dataclasses.replace(C.bench_cfg(), n_docs=N_DOCS,
                              train_queries=512, epochs=25)
    corpus = synth_corpus(0, cfg.n_docs, cfg.dim, cfg.vocab, topic_noise=0.5)
    index = cl.build_index(cfg, jax.random.key(0), corpus.embeddings,
                           corpus.doc_terms, corpus.doc_weights)
    tq = synth_queries(1, corpus, cfg.train_queries)
    _, feats, labels = tl.make_labels(cfg, index, tq.q_dense, tq.q_terms,
                                      tq.q_weights)
    index.lstm_params, _ = tl.train_selector(cfg, jax.random.key(2),
                                             np.asarray(feats),
                                             np.asarray(labels))
    qs = synth_queries(9, corpus, N_QUERIES, dense_noise=0.30,
                       term_noise_frac=0.4)
    rows = []

    # ---- in-memory backend: bucketed batching --------------------------
    engine = RetrievalEngine(cfg, index, max_batch=MAX_BATCH)
    ids, sizes, wall = _serve(engine, qs, N_QUERIES, BATCH_CYCLE)
    st = engine.stats()
    mem_row = {
        "backend": "in-memory",
        "MRR@10": round(mrr_at(ids, qs.rel_doc), 4),
        # p50/p99 are steady-state (jit-compile batches excluded)
        "p50_batch_ms": st["p50_ms"], "p99_batch_ms": st["p99_ms"],
        "qps_total": round(N_QUERIES / wall, 1),
        "qps_steady": st["qps_steady"],
        "compiled_buckets": st["compiled_buckets"],
        "n_batches": st["n_batches"],
    }
    rows.append(mem_row)

    # ---- seed-equivalent on-disk op count ------------------------------
    # the pre-engine per-query loop read one block per (query, selected
    # cluster); that count is sum(sel_mask) over the query set.
    _, _, diag = cl.retrieve(cfg, index, qs.q_dense, qs.q_terms, qs.q_weights)
    seed_ops = int(np.asarray(diag["sel_mask"]).sum())

    # ---- on-disk backend: dedup + LRU cache + prefetch -----------------
    tmp = tempfile.mkdtemp()
    blocks = dk.DiskClusterStore(os.path.join(tmp, "blocks.bin"),
                                 corpus.embeddings, index.cluster_docs)
    with RetrievalEngine(cfg, index,
                         store=DiskStore(blocks, index.cluster_docs),
                         max_batch=MAX_BATCH,
                         cache_capacity=cfg.n_clusters) as deng:
        ids_d, _, wall_d = _serve(deng, qs, N_QUERIES, (MAX_BATCH,))
    # stats after close(): prefetch worker drained, I/O counters final
    ds = deng.stats()
    io, cache = ds["io"], ds["cache"]
    disk_row = {
        "backend": "on-disk (engine)",
        "MRR@10": round(mrr_at(ids_d, qs.rel_doc), 4),
        "p50_batch_ms": ds["p50_ms"], "p99_batch_ms": ds["p99_ms"],
        "qps_total": round(N_QUERIES / wall_d, 1),
        "qps_steady": ds["qps_steady"],
        "block_read_ops": io["n_ops"],
        "seed_equiv_ops": seed_ops,
        "io_op_reduction": round(seed_ops / max(io["n_ops"], 1), 2),
        "bytes_read": io["bytes"],
        "mb_read": round(io["bytes"] / 2**20, 2),
        "io_model_ms": io["model_ms"],
        "cache_hit_rate": cache["hit_rate"],
        "prefetch_enqueued": ds["prefetch_enqueued"],
    }
    rows.append(disk_row)
    assert io["n_ops"] < seed_ops, \
        f"engine read {io['n_ops']} blocks, seed loop would read {seed_ops}"

    # ---- format-v2 PQ code shards through the same engine ---------------
    from repro import index as index_lib
    from repro.core import quant as quant_lib
    index.quantizer = quant_lib.train_pq(jax.random.key(3),
                                         corpus.embeddings, 12, rotate=True)
    pq_dir = os.path.join(tmp, "index_pq")
    emb = np.asarray(corpus.embeddings)
    index_lib.write_index(pq_dir, cfg, index, emb, n_shards=8,
                          format_version=index_lib.FORMAT_VERSION_PQ)
    index.quantizer = None
    reader = index_lib.IndexReader.open(pq_dir, verify="size")
    with reader.engine(max_batch=MAX_BATCH,
                       cache_capacity=cfg.n_clusters) as peng:
        ids_p, _, wall_p = _serve(peng, qs, N_QUERIES, (MAX_BATCH,))
    ps = peng.stats()
    pio, pcache = ps["io"], ps["cache"]
    mrr_pq = round(mrr_at(ids_p, qs.rel_doc), 4)
    pq_row = {
        "backend": "pq-sharded (v2 index)",
        "MRR@10": mrr_pq,
        "mrr_delta_vs_inmemory": round(abs(mrr_pq - mem_row["MRR@10"]), 4),
        "p50_batch_ms": ps["p50_ms"], "p99_batch_ms": ps["p99_ms"],
        "qps_total": round(N_QUERIES / wall_p, 1),
        "qps_steady": ps["qps_steady"],
        "block_read_ops": pio["n_ops"],
        "bytes_read": pio["bytes"],
        "mb_read": round(pio["bytes"] / 2**20, 2),
        "code_byte_reduction": round(io["bytes"] / max(pio["bytes"], 1), 1),
        "cache_hit_rate": pcache["hit_rate"],
        # ADC serving: raw codes scored in-kernel, zero host decode
        "use_adc": ps["use_adc"],
        "adc_ms": ps.get("adc_ms", 0.0),
        "lut_build_ms": ps.get("lut_build_ms", 0.0),
        "decode_ms": ps.get("decode_ms", 0.0),
    }
    rows.append(pq_row)
    assert pq_row["mrr_delta_vs_inmemory"] <= 0.02, \
        f"PQ serving MRR {mrr_pq} vs in-memory {mem_row['MRR@10']}"
    assert ps["use_adc"], "v2 code shards should auto-enable ADC serving"
    assert pq_row["decode_ms"] == 0.0, \
        f"ADC path decoded floats on the host: decode_ms={pq_row['decode_ms']}"

    # ---- decode-then-score over the SAME v2 index: MRR must be identical
    with reader.engine(max_batch=MAX_BATCH, cache_capacity=cfg.n_clusters,
                       use_adc=False) as qeng:
        ids_q, _, _ = _serve(qeng, qs, N_QUERIES, (MAX_BATCH,))
    dst = qeng.stats()
    mrr_decode = round(mrr_at(ids_q, qs.rel_doc), 4)
    assert mrr_decode == mrr_pq, \
        f"ADC MRR {mrr_pq} != decode-then-score MRR {mrr_decode}"
    pq_row["mrr_decode_path"] = mrr_decode
    pq_row["decode_path_decode_ms"] = dst.get("decode_ms", 0.0)
    pq_row["decode_path_p50_batch_ms"] = dst["p50_ms"]

    # acceptance: code shards off disk now serve FASTER than the in-memory
    # float backend (ADC LUT scoring + fused tail beat the dense einsum)
    assert pq_row["p50_batch_ms"] < mem_row["p50_batch_ms"], \
        (f"pq-sharded p50 {pq_row['p50_batch_ms']}ms not under in-memory "
         f"p50 {mem_row['p50_batch_ms']}ms")

    # ---- stage breakdown + tracing overhead (pq-sharded engine) ---------
    # Same engine, two steady passes: pass 1 with tracing off measures the
    # clean p50; reset_stats + sample_rate=1.0, pass 2 yields the traced
    # p50 and the per-stage span totals. check_regression.py gates the
    # traced/untraced p50 ratio at 1.05 (+0.2ms timer-noise floor).
    from repro.obs import Tracer
    tracer = Tracer(sample_rate=0.0, capacity=4096)
    with reader.engine(max_batch=MAX_BATCH, cache_capacity=cfg.n_clusters,
                       tracer=tracer) as teng:
        _serve(teng, qs, N_QUERIES, (MAX_BATCH,))        # untraced pass
        p50_untraced = teng.stats()["p50_ms"]
        teng.reset_stats()
        tracer.sample_rate = 1.0
        _serve(teng, qs, N_QUERIES, (MAX_BATCH,))        # traced pass
        p50_traced = teng.stats()["p50_ms"]
    batch_wall = covered = 0.0
    for t in tracer.traces:
        if t.name != "batch":
            continue
        batch_wall += float(t.spans[0].annot.get("batch_ms", 0.0))
        # depth-1 stages only (disk_fetch nests under cache_fetch); `pad`
        # precedes the batch_ms clock, so it is not part of coverage
        covered += sum(sp.dur_ms or 0.0 for sp in t.spans
                       if sp.depth == 1 and sp.name != "pad")
    coverage = round(covered / max(batch_wall, 1e-9), 4)
    pq_row["stage_breakdown_ms"] = {
        name: agg["ms"] for name, agg in
        sorted(tracer.span_totals("batch").items())}
    pq_row["span_coverage_frac"] = coverage
    pq_row["trace_overhead"] = {
        "p50_ms_untraced": p50_untraced, "p50_ms_traced": p50_traced,
        "frac": round(p50_traced / max(p50_untraced, 1e-9), 4),
    }
    assert coverage >= 0.9, \
        (f"stage spans cover only {coverage:.0%} of traced batch wall time "
         f"({covered:.1f}/{batch_wall:.1f} ms)")

    # ---- reduced-precision v1 shard dtypes ------------------------------
    for dt in ("bfloat16", "int8"):
        vdir = os.path.join(tmp, f"index_{dt}")
        index_lib.write_index(vdir, cfg, index, emb, n_shards=8,
                              block_dtype=dt)
        vrd = index_lib.IndexReader.open(vdir, verify="size")
        with vrd.engine(max_batch=MAX_BATCH,
                        cache_capacity=cfg.n_clusters) as veng:
            ids_v, _, wall_v = _serve(veng, qs, N_QUERIES, (MAX_BATCH,))
        vs = veng.stats()
        mrr_v = round(mrr_at(ids_v, qs.rel_doc), 4)
        v_row = {
            "backend": f"sharded-{dt} (v1 index)",
            "MRR@10": mrr_v,
            "mrr_delta_vs_inmemory": round(abs(mrr_v - mem_row["MRR@10"]), 4),
            "p50_batch_ms": vs["p50_ms"], "p99_batch_ms": vs["p99_ms"],
            "qps_total": round(N_QUERIES / wall_v, 1),
            "bytes_read": vs["io"]["bytes"],
            "byte_reduction_vs_float32": round(
                io["bytes"] / max(vs["io"]["bytes"], 1), 1),
            "decode_ms": vs.get("decode_ms", 0.0),
            "cache_hit_rate": vs["cache"]["hit_rate"],
        }
        rows.append(v_row)
        assert v_row["mrr_delta_vs_inmemory"] <= 0.02, \
            f"{dt} serving MRR {mrr_v} vs in-memory {mem_row['MRR@10']}"

    # ---- cache-budget sweep: codes vs floats at the same byte budget ----
    # budgets are in float32-block equivalents (cap*dim*4 bytes each); the
    # code-backed engine fits 4*dim/nsub more clusters in the same bytes,
    # so its hit rate climbs far sooner.
    sweep = []
    n_sweep = 128
    for budget in (cfg.n_clusters // 16, cfg.n_clusters // 8,
                   cfg.n_clusters // 4):
        with RetrievalEngine(cfg, index,
                             store=DiskStore(blocks, index.cluster_docs),
                             max_batch=MAX_BATCH, cache_capacity=budget,
                             prefetch=False) as feng:
            _serve(feng, qs, n_sweep, (MAX_BATCH,))
        with reader.engine(max_batch=MAX_BATCH, cache_capacity=budget,
                           prefetch=False) as ceng:
            _serve(ceng, qs, n_sweep, (MAX_BATCH,))
        f_hit = feng.stats()["cache"]["hit_rate"]
        c_hit = ceng.stats()["cache"]["hit_rate"]
        sweep.append({"budget_float_blocks": budget,
                      "float_hit_rate": f_hit, "code_hit_rate": c_hit,
                      "hit_rate_gain": round(c_hit - f_hit, 4)})

    # ---- multi-host scatter-gather router: QPS scaling + failover -------
    # The bench box is a single core, so raw host compute cannot scale; the
    # rows instead model a remote block store with a simulated per-request
    # service time (sleep(base_ms + per_block_ms * n_unique_blocks) inside
    # each EngineHost, concurrent across host threads). What the ratio then
    # measures is the router's scatter-gather structure: with H hosts each
    # host fetches ~1/H of the unique blocks, so the simulated I/O wall
    # shrinks ~H-fold while the router-side serial compute (stage-I/II,
    # merge, fuse) stays fixed — an Amdahl curve, gated at >=1.8x for 3
    # hosts by check_regression.py. Results are EXACT: every row's doc ids
    # must match the single-host pq-sharded engine bitwise, including the
    # failover row that serves with one of three hosts killed mid-run.
    from repro.engine import ShardRouter
    SIM_LATENCY = (0.25, 1.5)       # (base_ms, per_block_ms) per host call
    router_rows = []
    for hosts in (1, 2, 3):
        rrd = index_lib.IndexReader.open(pq_dir, verify="none")
        with ShardRouter.local(rrd, n_hosts=hosts, replication=1,
                               cache_capacity=cfg.n_clusters,
                               sim_latency=SIM_LATENCY,
                               max_batch=MAX_BATCH) as router:
            _serve(router, qs, N_QUERIES, (MAX_BATCH,))   # compile/warm pass
            router.reset_stats()
            ids_r, _, wall_r = _serve(router, qs, N_QUERIES, (MAX_BATCH,))
            rst = router.stats()
        assert np.array_equal(ids_r, ids_p), \
            f"router({hosts} hosts) ids diverged from single-host engine"
        assert rst["failed_requests"] == 0 and rst["degraded_requests"] == 0
        router_rows.append({
            "backend": f"router-{hosts}host (v2 index, simulated I/O)",
            "hosts": hosts, "replication": 1,
            "sim_base_ms": SIM_LATENCY[0],
            "sim_per_block_ms": SIM_LATENCY[1],
            "MRR@10": mrr_pq,
            "p50_batch_ms": rst["p50_ms"], "p99_batch_ms": rst["p99_ms"],
            "qps_total": round(N_QUERIES / wall_r, 1),
            "failed_requests": rst["failed_requests"],
            "degraded_requests": rst["degraded_requests"],
        })
    scale_3x = round(router_rows[2]["qps_total"]
                     / max(router_rows[0]["qps_total"], 1e-9), 2)
    router_rows[2]["qps_vs_1host"] = scale_3x

    # failover: 3 hosts with replication 2, host 0 killed after warmup —
    # every batch reroutes its shards to replicas, zero failed requests,
    # and the ids still match the single-host engine exactly.
    rrd = index_lib.IndexReader.open(pq_dir, verify="none")
    with ShardRouter.local(rrd, n_hosts=3, replication=2,
                           cache_capacity=cfg.n_clusters,
                           sim_latency=SIM_LATENCY,
                           max_batch=MAX_BATCH) as router:
        _serve(router, qs, N_QUERIES, (MAX_BATCH,))       # compile/warm pass
        router.hosts[0].kill()
        router.reset_stats()
        ids_f, _, wall_f = _serve(router, qs, N_QUERIES, (MAX_BATCH,))
        fst = router.stats()
    assert np.array_equal(ids_f, ids_p), \
        "failover router ids diverged from single-host engine"
    assert fst["failed_requests"] == 0 and fst["degraded_requests"] == 0, \
        f"failover pass dropped requests: {fst['failed_requests']} failed, " \
        f"{fst['degraded_requests']} degraded"
    assert fst["failovers"] > 0
    router_rows.append({
        "backend": "router-3host-failover (1 of 3 killed, replication 2)",
        "hosts": 3, "replication": 2,
        "sim_base_ms": SIM_LATENCY[0], "sim_per_block_ms": SIM_LATENCY[1],
        "MRR@10": mrr_pq,
        "p50_batch_ms": fst["p50_ms"], "p99_batch_ms": fst["p99_ms"],
        "qps_total": round(N_QUERIES / wall_f, 1),
        "failed_requests": fst["failed_requests"],
        "degraded_requests": fst["degraded_requests"],
        "failovers": fst["failovers"],
    })

    result = {"table": "serve_engine", "n_docs": N_DOCS,
              "n_queries": N_QUERIES, **C.bench_meta(cfg),
              "cache_sweep": sweep, "router_scaling": router_rows,
              "rows": rows}
    out = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "BENCH_serve.json"))
    with open(out, "w") as f:
        json.dump(result, f, indent=1)
    print(f"wrote {out}")
    return result


if __name__ == "__main__":
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    res = run()
    for r in res["rows"]:
        print(json.dumps(r))
    for r in res["router_scaling"]:
        print(json.dumps(r))
