"""Selector-training benchmark: streaming label generation, bucketed
training, calibration, and publish/hot-reload — measured end to end
against a built on-disk index.

What the repro.train subsystem buys: the seed trainer needed the whole
embedding matrix in RAM to label queries (`full_dense_topk`); streaming
label generation computes the exact same supervision through the index's
own sharded block store with every read bounded — so selector training
runs in the same corpus regime as the PR-2/3 builds (np.memmap, corpus >
RAM). Calibration then turns the trained selector into an operating point
(theta, cluster budget) hit on held-out queries instead of a hand-picked
threshold.

Writes BENCH_train.json at the repo root (stamped with git SHA + config;
every field is documented in docs/BENCHMARKS.md):
  label_gen           streaming wall/throughput, blocks + bytes read,
                      in-RAM reference wall, parity_exact (asserted)
  train               wall, optimizer steps, steps/s, bucket lengths,
                      final loss, effective pos_weight
  calibration         chosen operating point (theta, budget) for the
                      recall target + the default point's recall, swept
                      at expand_depth=0 (the pre-hybrid baseline)
  hybrid              the hybrid candidate-generation operating point:
                      theta x budget x expansion-depth sweep (selector
                      retrained on expanded candidate sequences), chosen
                      for best recall at the BASELINE budget — same
                      est_read_bytes, higher stage-1 ceiling; per-depth
                      `sweep` rows record ceiling + best recall@budget
  recall_at_budget    top-level copy (the hybrid point) — the CI
                      regression gate fails on >0.02 drift vs the
                      merge-base baseline, and check_regression's
                      intra-train gate requires hybrid >= baseline at
                      <= baseline read bytes within this file
  serve               MRR@10 served by a live engine before the publish
                      (untrained fallback), with the trained selector at
                      the default theta/budget, and at the published
                      hybrid point (fusion="rrf" + expansion) after a
                      reload_selector() hot swap; failed_requests across
                      the swap (asserted 0). Also asserts depth-0 +
                      fusion="interp" is BITWISE the default pipeline.

Standalone: PYTHONPATH=src python -m benchmarks.train_selector
"""

import dataclasses
import json
import os
import tempfile
import time

import jax
import numpy as np

from benchmarks import common as C
from repro import index as index_lib
from repro import train as train_lib
from repro.core import train_lstm as tl
from repro.data import mrr_at, synth_queries
from repro.engine import InMemoryStore, pipeline as pipe_lib

N_SHARDS = 8
CHUNK_CLUSTERS = 32
N_HOLDOUT = 256
BATCH = 32
TARGET_RECALL = 0.90
THETAS = (0.01, 0.02, 0.05, 0.1, 0.2, 0.3, 0.5, 0.7)
DEPTHS = (0, 1, 2, 3)            # stage-1 expansion depths swept
HYBRID_FUSION = "rrf"            # fusion method published with the hybrid op


def main():
    cfg, corpus, index = C.corpus_and_index()
    emb = np.asarray(corpus.embeddings)
    out = os.path.join(tempfile.mkdtemp(), "bench_train_idx")
    index_lib.write_index(out, cfg, index, emb, n_shards=N_SHARDS)
    reader = index_lib.IndexReader.open(out)
    lcfg, lindex = reader.load_index()
    store = reader.open_store(cluster_docs=lindex.cluster_docs)

    train_q = synth_queries(1, corpus, cfg.train_queries)
    hold_q = C.test_queries(corpus, N_HOLDOUT)
    nq_hold = int(np.asarray(hold_q.q_dense).shape[0])

    # -- 1. label generation: streamed vs in-RAM ---------------------------
    t0 = time.perf_counter()
    cand_r, feats_r, labels_r = tl.make_labels(
        cfg, index, train_q.q_dense, train_q.q_terms, train_q.q_weights)
    jax.block_until_ready(labels_r)
    inram_wall = time.perf_counter() - t0

    label_cfg = train_lib.LabelConfig(chunk_clusters=CHUNK_CLUSTERS)
    t0 = time.perf_counter()
    ls = train_lib.make_labels_streaming(
        lcfg, lindex, store, train_q.q_dense, train_q.q_terms,
        train_q.q_weights, label_cfg=label_cfg)
    stream_wall = time.perf_counter() - t0
    parity = (np.array_equal(np.asarray(cand_r), ls.cand)
              and np.array_equal(np.asarray(feats_r), ls.feats)
              and np.array_equal(np.asarray(labels_r), ls.labels))
    assert parity, "streaming labels diverged from the in-RAM oracle"
    label_gen = {
        "n_queries": ls.n_queries,
        "chunk_clusters": CHUNK_CLUSTERS,
        "wall_s": round(stream_wall, 3),
        "queries_per_s": round(ls.n_queries / stream_wall, 1),
        "blocks_read": ls.stats.blocks_read,
        "bytes_read": ls.stats.bytes_read,
        "n_fetches": ls.stats.n_fetches,
        "inram_wall_s": round(inram_wall, 3),
        "pos_rate": round(ls.pos_rate, 4),
        "parity_exact": bool(parity),
    }
    print(f"label_gen: {label_gen}", flush=True)

    # -- 2. bucketed training ----------------------------------------------
    trainer = train_lib.SelectorTrainer(
        cfg, train_lib.SelectorTrainConfig(use_kernel=False))
    t0 = time.perf_counter()
    params, hist = trainer.fit(jax.random.key(2), ls.feats, ls.labels)
    train_wall = time.perf_counter() - t0
    buckets = train_lib.bucket_lengths(cfg, ls.feats, ls.labels)
    steps = train_lib.n_batches_per_epoch(buckets, 256) * cfg.epochs
    train_stats = {
        "wall_s": round(train_wall, 3),
        "steps": steps,
        "steps_per_s": round(steps / train_wall, 1),
        "epochs": cfg.epochs,
        "bucket_lengths": sorted(int(b) for b in np.unique(buckets)),
        "final_loss": round(hist[-1], 4),
        "pos_weight": trainer.pos_weight,
    }
    print(f"train: {train_stats}", flush=True)

    # -- 3. calibration on held-out queries --------------------------------
    hold_ls = train_lib.make_labels_streaming(
        lcfg, lindex, store, hold_q.q_dense, hold_q.q_terms,
        hold_q.q_weights, label_cfg=label_cfg)
    probs = train_lib.selector_probs(params, hold_ls.feats)
    budgets = [b for b in (4, 8, 16, 32, 64) if b <= cfg.n_candidates]
    table = train_lib.calibration_table(
        hold_ls, probs, np.asarray(lindex.doc_cluster),
        thetas=sorted(set(THETAS) | {cfg.theta}), budgets=budgets,
        block_bytes=store.block_bytes)
    op = train_lib.choose_operating_point(table,
                                          target_recall=TARGET_RECALL)
    pos_clusters = np.asarray(lindex.doc_cluster)[hold_ls.dense_ids]
    default_recall, default_sel = train_lib.recall_at_budget(
        hold_ls.cand, probs, pos_clusters, cfg.theta, cfg.max_selected)
    # recall if every stage-1 candidate were selected: the Stage-II
    # selector can only choose among them, so this bounds any operating
    # point — recall_frac_of_ceiling is the selector's own quality
    ceiling, _ = train_lib.recall_at_budget(
        hold_ls.cand, probs, pos_clusters, -np.inf, cfg.n_candidates)
    calibration = {
        "target_recall": TARGET_RECALL,
        "theta": op["theta"],
        "budget": op["budget"],
        "recall_at_budget": op["recall"],
        "avg_selected": op["avg_selected"],
        "est_read_bytes": op["est_read_bytes"],
        "target_met": op["target_met"],
        "stage1_ceiling": round(ceiling, 4),
        "recall_frac_of_ceiling": round(op["recall"] / max(ceiling, 1e-9),
                                        4),
        "default": {"theta": cfg.theta, "budget": cfg.max_selected,
                    "recall": round(default_recall, 4),
                    "avg_selected": round(default_sel, 2)},
    }
    print(f"calibration: {calibration}", flush=True)

    # -- 3b. hybrid candidate generation: expansion-depth sweep ------------
    depths = [d for d in DEPTHS
              if cfg.n_candidates * (1 + d) <= cfg.n_clusters]
    dmax = max(depths)
    # retrain the selector on EXPANDED candidate sequences so Stage II can
    # rank clusters the sparse seeds never surfaced; reuses the streamed
    # dense ids (stage-1-independent), so no second corpus pass
    t0 = time.perf_counter()
    train_ls_h = train_lib.relabel_for_config(
        dataclasses.replace(lcfg, expand_depth=dmax), lindex,
        train_q.q_dense, train_q.q_terms, train_q.q_weights, ls.dense_ids)
    trainer_h = train_lib.SelectorTrainer(
        dataclasses.replace(cfg, expand_depth=dmax),
        train_lib.SelectorTrainConfig(use_kernel=False))
    params_h, _ = trainer_h.fit(jax.random.key(2), train_ls_h.feats,
                                train_ls_h.labels)
    hybrid_train_wall = time.perf_counter() - t0
    sweep = train_lib.expansion_sweep(
        lcfg, lindex, params_h, hold_q.q_dense, hold_q.q_terms,
        hold_q.q_weights, hold_ls.dense_ids, depths=depths,
        thetas=sorted(set(THETAS) | {cfg.theta}), budgets=budgets,
        block_bytes=store.block_bytes)
    rows_h = [r for e in sweep for r in e["rows"]]
    # best recall at the BASELINE budget: expansion must pay in recall at
    # the same block-I/O bill, not by reading more
    hop = train_lib.choose_operating_point(rows_h, target_budget=op["budget"])
    ceil_by_depth = {e["depth"]: e["stage1_ceiling"] for e in sweep}
    hybrid = {
        "fusion": HYBRID_FUSION,
        "rrf_k": float(cfg.rrf_k),
        "expand_depth": hop["depth"],
        "n_candidates": hop["n_candidates"],
        "theta": hop["theta"],
        "budget": hop["budget"],
        "recall_at_budget": hop["recall"],
        "avg_selected": hop["avg_selected"],
        "est_read_bytes": hop["est_read_bytes"],
        "stage1_ceiling": ceil_by_depth[hop["depth"]],
        "baseline_ceiling": calibration["stage1_ceiling"],
        "target_recall": TARGET_RECALL,
        "target_met": hop["recall"] >= TARGET_RECALL,
        "train_wall_s": round(hybrid_train_wall, 3),
        "sweep": [dict(
            {k: e[k] for k in ("depth", "n_candidates", "stage1_ceiling")},
            best_recall_at_budget=max(r["recall"] for r in e["rows"]
                                      if r["budget"] <= op["budget"]))
            for e in sweep],
    }
    print(f"hybrid: {hybrid}", flush=True)
    # the point of the PR: deeper candidates buy recall at the same budget
    assert hop["budget"] <= op["budget"], (hop, op)
    assert hop["recall"] > calibration["stage1_ceiling"], \
        f"hybrid recall {hop['recall']} not above baseline stage-1 " \
        f"ceiling {calibration['stage1_ceiling']}"

    # -- 4. publish + live hot-reload serving ------------------------------
    engine = reader.engine(max_batch=BATCH)
    failed = 0

    def serve_ids():
        nonlocal failed
        out_ids = []
        for lo in range(0, nq_hold, BATCH):
            try:
                ids, _ = engine.retrieve(hold_q.q_dense[lo:lo + BATCH],
                                         hold_q.q_terms[lo:lo + BATCH],
                                         hold_q.q_weights[lo:lo + BATCH])
                out_ids.append(np.asarray(ids))
            except Exception:
                failed += 1
                raise
        return np.concatenate(out_ids)

    mrr_untrained = mrr_at(serve_ids(), hold_q.rel_doc)

    # trained selector at the DEFAULT operating point (in-memory pipeline:
    # numerically identical to v1 on-disk serving)
    mem = InMemoryStore(corpus.embeddings, lindex.cluster_docs)
    ids_def, _, _ = pipe_lib.retrieve(
        cfg, lindex, mem, hold_q.q_dense, hold_q.q_terms, hold_q.q_weights,
        selector_params=params)
    mrr_default = mrr_at(np.asarray(ids_def), hold_q.rel_doc)

    # the hybrid knobs must default OFF: explicit depth-0 + interp is
    # bitwise the pipeline above (acceptance criterion — MRR identical)
    ids_exp, _, _ = pipe_lib.retrieve(
        dataclasses.replace(cfg, fusion="interp", expand_depth=0), lindex,
        mem, hold_q.q_dense, hold_q.q_terms, hold_q.q_weights,
        selector_params=params)
    assert np.array_equal(np.asarray(ids_def), np.asarray(ids_exp)), \
        "explicit fusion='interp'/expand_depth=0 diverged from default"

    # publish the HYBRID operating point: retrained selector + calibrated
    # theta/budget + expansion depth + RRF fusion, one atomic generation.
    # reload_selector() must recompile Stage I (expand_depth changed).
    report = train_lib.publish_selector(
        out, params_h, theta=hop["theta"], budget=hop["budget"],
        expand_depth=hop["depth"], fusion=HYBRID_FUSION,
        calibration=rows_h, label_config={"chunk_clusters": CHUNK_CLUSTERS},
        train_meta=train_stats)
    gen = engine.reload_selector()
    assert gen == report["generation"] == 1, (gen, report)
    assert engine.cfg.expand_depth == hop["depth"] \
        and engine.cfg.fusion == HYBRID_FUSION, engine.cfg
    mrr_calibrated = mrr_at(serve_ids(), hold_q.rel_doc)
    engine.close()
    assert failed == 0, f"{failed} retrieve calls failed across the swap"
    serve = {
        "MRR@10_untrained": round(mrr_untrained, 4),
        "MRR@10_default": round(mrr_default, 4),
        "MRR@10_calibrated": round(mrr_calibrated, 4),
        "fusion": engine.stats()["fusion"],
        "expand_depth": engine.stats()["expand_depth"],
        "generation": gen,
        "selector_reloads": engine.stats()["selector_reloads"],
        "failed_requests": failed,
    }
    print(f"serve: {serve}", flush=True)

    out_path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_train.json")
    payload = {
        **C.bench_meta(cfg),
        "label_gen": label_gen,
        "train": train_stats,
        "calibration": calibration,
        "hybrid": hybrid,
        "recall_at_budget": hybrid["recall_at_budget"],
        "serve": serve,
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"wrote {out_path}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
