"""Paper Fig. 2: quality/latency vs average number of clusters selected, for
two cluster-partition sizes N (flat + PQ variants)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro.core import clusd as cl
from repro.core import quant as qt
from repro.data import mrr_at, recall_at


def run():
    curves = []
    for n_clusters in (128, 256):
        cfg, corpus, index, params, _, _ = C.trained_index(n_clusters)
        index.lstm_params = params
        qs = C.test_queries(corpus, n=128)
        pq = qt.train_pq(jax.random.key(3), corpus.embeddings, nsub=8,
                         iters=5)
        for quantized in (False, True):
            index.quantizer = pq if quantized else None
            pts = []
            for theta in (0.9, 0.5, 0.2, 0.05, 0.02):
                cfg_t = dataclasses.replace(cfg, theta=theta)
                fn = jax.jit(lambda qd, qt_, qw: cl.retrieve(
                    cfg_t, index, qd, qt_, qw, selector_params=params))
                (ids, _, diag), lat = C.timed(fn, qs.q_dense, qs.q_terms,
                                              qs.q_weights, reps=2)
                pts.append({
                    "theta": theta,
                    "avg_sel": round(float(diag["n_selected"].mean()), 2),
                    "pctD": round(100 * float(
                        diag["frac_docs_scanned"].mean()), 3),
                    "MRR@10": round(mrr_at(np.asarray(ids), qs.rel_doc), 4),
                    "R@100": round(recall_at(np.asarray(ids), qs.rel_doc,
                                             100), 4),
                    "latency_ms": round(lat, 1)})
            curves.append({"N": n_clusters,
                           "store": "PQ m=8" if quantized else "flat",
                           "points": pts})
        index.quantizer = None
    return {"table": "fig2_nclusters", "curves": curves}
