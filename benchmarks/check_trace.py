"""Validate a repro.obs trace export (schema + structural invariants).

Formats (picked by suffix, matching repro.obs.write_trace):

  * `.jsonl` — one span per line:
      {"trace": int, "trace_name": str, "span": str, "index": int,
       "parent": int, "depth": int, "t0_ms": float, "dur_ms": float, ...}
    Checked per trace: span 0 is the root (parent -1, depth 0, t0 0),
    every other span's parent precedes it, depth == parent depth + 1, and
    every span lies inside its parent's [t0, t0 + dur] window (0.1 ms
    slack for rounding).
    Cross-host join (router traces): every `host_serve` span's parent
    must be a `scatter` span and carry an integer `host` annotation;
    every child of a `scatter` span must be a `gather` span or carry the
    `host` annotation (grafted host-side work). The generic parent-window
    rule already pins grafted spans inside the scatter window.
  * anything else — Chrome trace JSON: {"traceEvents": [...]} where every
    event is a complete ("ph": "X") event with name/ts/dur/pid/tid.
    Events whose args carry `host` must ride a per-host lane: a string
    tid ending in `.host<i>` (the exporter routes host-attributed spans
    to their own lanes).

Exit 0 = valid, 1 = violations (each printed). CI runs this on the
serve smoke trace (see .github/workflows/ci.yml):

  PYTHONPATH=src python -m repro.launch.serve --index-dir $IDX \
      --queries 8 --trace-out /tmp/trace.jsonl
  python benchmarks/check_trace.py /tmp/trace.jsonl \
      --require-spans stage1,stage2_select,fused_score_topk
"""

import argparse
import json
import sys

REQUIRED = {"trace": int, "trace_name": str, "span": str, "index": int,
            "parent": int, "depth": int, "t0_ms": (int, float),
            "dur_ms": (int, float)}
SLACK_MS = 0.1          # to_dict rounds to 3 decimals; allow rounding skew


def check_jsonl(path):
    bad = []
    traces = {}
    with open(path) as f:
        for ln, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                d = json.loads(line)
            except json.JSONDecodeError as e:
                bad.append(f"line {ln}: not valid JSON ({e})")
                continue
            for key, typ in REQUIRED.items():
                if key not in d:
                    bad.append(f"line {ln}: missing key {key!r}")
                elif not isinstance(d[key], typ) or isinstance(d[key], bool):
                    bad.append(f"line {ln}: {key}={d[key]!r} is not "
                               f"{typ}")
            if bad and bad[-1].startswith(f"line {ln}"):
                continue
            if d["dur_ms"] < 0 or d["t0_ms"] < 0 or d["depth"] < 0:
                bad.append(f"line {ln}: negative t0/dur/depth: {d}")
            traces.setdefault(d["trace"], []).append((ln, d))
    for tid, spans in traces.items():
        by_index = {d["index"]: d for _, d in spans}
        root = by_index.get(0)
        if root is None or root["parent"] != -1 or root["depth"] != 0 \
                or root["t0_ms"] != 0:
            bad.append(f"trace {tid}: span 0 is not a well-formed root "
                       f"({root})")
            continue
        for ln, d in spans:
            if d["index"] == 0:
                continue
            parent = by_index.get(d["parent"])
            if parent is None or d["parent"] >= d["index"]:
                bad.append(f"line {ln}: parent {d['parent']} does not "
                           f"precede span {d['index']} in trace {tid}")
                continue
            if d["depth"] != parent["depth"] + 1:
                bad.append(f"line {ln}: depth {d['depth']} != parent "
                           f"depth {parent['depth']} + 1")
            if d["t0_ms"] + SLACK_MS < parent["t0_ms"] or \
                    d["t0_ms"] + d["dur_ms"] > \
                    parent["t0_ms"] + parent["dur_ms"] + SLACK_MS:
                bad.append(f"line {ln}: span {d['span']!r} "
                           f"[{d['t0_ms']}, {d['t0_ms'] + d['dur_ms']}] "
                           f"escapes parent {parent['span']!r} window")
        # cross-host join: host_serve spans are grafted host-side roots
        # and must hang off a scatter span with host attribution; scatter
        # children are either the gather leg or grafted host work
        for ln, d in spans:
            if d["span"] == "host_serve":
                parent = by_index.get(d["parent"])
                if parent is None or parent["span"] != "scatter":
                    bad.append(f"line {ln}: host_serve parent is "
                               f"{parent and parent['span']!r}, expected "
                               f"'scatter' (trace {tid})")
                if not isinstance(d.get("host"), int) \
                        or isinstance(d.get("host"), bool):
                    bad.append(f"line {ln}: host_serve lacks an integer "
                               f"'host' annotation (got "
                               f"{d.get('host')!r})")
            elif d["span"] == "scatter":
                for cln, c in spans:
                    if c["parent"] != d["index"] or c["index"] == 0:
                        continue
                    host_ok = isinstance(c.get("host"), int) \
                        and not isinstance(c.get("host"), bool)
                    if c["span"] != "gather" and not host_ok:
                        bad.append(
                            f"line {cln}: scatter child {c['span']!r} is "
                            f"neither 'gather' nor host-annotated "
                            f"(trace {tid})")
    names = {d["span"] for spans in traces.values() for _, d in spans}
    return bad, len(traces), names


def check_chrome(path):
    bad = []
    with open(path) as f:
        try:
            doc = json.load(f)
        except json.JSONDecodeError as e:
            return [f"not valid JSON ({e})"], 0, set()
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        return ["top-level 'traceEvents' list missing"], 0, set()
    names, tids = set(), set()
    for i, ev in enumerate(events):
        for key in ("name", "ph", "ts", "dur", "pid", "tid"):
            if key not in ev:
                bad.append(f"event {i}: missing {key!r}")
        if ev.get("ph") != "X":
            bad.append(f"event {i}: ph={ev.get('ph')!r}, expected complete "
                       f"event 'X'")
        if not isinstance(ev.get("ts"), (int, float)) or \
                not isinstance(ev.get("dur"), (int, float)) or \
                ev.get("dur", 0) < 0:
            bad.append(f"event {i}: non-numeric or negative ts/dur")
        host = (ev.get("args") or {}).get("host")
        if host is not None:
            # host-attributed spans must ride well-formed per-host lanes
            tid = ev.get("tid")
            if not isinstance(tid, str) or \
                    not tid.endswith(f".host{host}"):
                bad.append(f"event {i}: host={host!r} but tid={tid!r} is "
                           f"not a '.host{host}' lane")
        names.add(ev.get("name"))
        tids.add(ev.get("tid"))
    return bad, len(tids), names


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="Validate a repro.obs trace export.", epilog=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("trace", help="trace file (.jsonl or Chrome JSON)")
    ap.add_argument("--require-spans", default=None, metavar="A,B,...",
                    help="comma list of span names that must appear")
    ap.add_argument("--min-traces", type=int, default=1,
                    help="minimum number of traces expected (default 1)")
    args = ap.parse_args(argv)

    checker = check_jsonl if args.trace.endswith(".jsonl") else check_chrome
    bad, n_traces, names = checker(args.trace)
    if n_traces < args.min_traces:
        bad.append(f"only {n_traces} trace(s), expected >= "
                   f"{args.min_traces}")
    for want in (args.require_spans or "").split(","):
        if want and want not in names:
            bad.append(f"required span {want!r} never appears "
                       f"(saw: {sorted(n for n in names if n)})")
    for b in bad:
        print(f"TRACE INVALID: {b}")
    if not bad:
        print(f"trace OK: {args.trace} — {n_traces} trace(s), "
              f"{len(names)} span name(s)")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
