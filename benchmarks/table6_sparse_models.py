"""Paper Table 6: CluSD guided by sparse models of different quality
(SPLADE / uniCOIL / BM25 analogues = decreasing query-term fidelity)."""

import jax

from benchmarks import common as C
from repro.core import baselines as bl
from repro.core import clusd as cl
from repro.core import sparse as sparse_lib
from repro.data import synth_queries


def run():
    cfg, corpus, index, params, _, _ = C.trained_index()
    index.lstm_params = params
    rows = []
    # guide quality = query lexical fidelity (term_noise_frac)
    for noise, tag in [(0.1, "SPLADE-like (strong)"),
                       (0.3, "uniCOIL-like (medium)"),
                       (0.6, "BM25-like (weak)")]:
        qs = synth_queries(21, corpus, 192, term_noise_frac=noise)
        sid, _ = sparse_lib.sparse_retrieve_topk(
            index.sparse_index, qs.q_terms, qs.q_weights, cfg.k_sparse)
        s_q = C.quality(sid, qs)
        ids_c, _, diag = jax.jit(
            lambda qd, qt, qw: cl.retrieve(cfg, index, qd, qt, qw,
                                           selector_params=params))(
            qs.q_dense, qs.q_terms, qs.q_weights)
        ids_r, _, _ = jax.jit(
            lambda qd, qt, qw: bl.rerank_retrieve(cfg, index, qd, qt, qw))(
            qs.q_dense, qs.q_terms, qs.q_weights)
        rows.append({"guide": tag, "S_MRR@10": s_q["MRR@10"],
                     "S+Rerank_MRR@10": C.quality(ids_r, qs)["MRR@10"],
                     "S+CluSD_MRR@10": C.quality(ids_c, qs)["MRR@10"],
                     "S+CluSD_R@100": C.quality(ids_c, qs)["R@100"],
                     "avg_sel": round(float(diag["n_selected"].mean()), 1)})
    return {"table": "table6_sparse_models", "rows": rows}
