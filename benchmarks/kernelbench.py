"""Kernel micro-benchmarks: Pallas (interpret on CPU — correctness-scale
timing only; TPU is the perf target) vs the jnp reference, plus agreement
check at benchmark shapes."""

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro.kernels.cluster_score import cluster_score_ref
from repro.kernels.cluster_score.kernel import cluster_score_pallas
from repro.kernels.lstm import lstm_sequence_ref
from repro.kernels.lstm.kernel import lstm_sequence_pallas


def run():
    rng = np.random.default_rng(0)
    rows = []
    # cluster_score at paper-ish shape
    B, dim, N, cap, S = 8, 768 // 4, 256, 128, 16
    q = jnp.asarray(rng.standard_normal((B, dim)), jnp.float32)
    blocks = jnp.asarray(rng.standard_normal((N, cap, dim)), jnp.float32)
    sel = jnp.asarray(rng.integers(0, N, (B, S)), jnp.int32)
    ref = jax.jit(cluster_score_ref)
    _, t_ref = C.timed(ref, q, blocks, sel)
    out_k = cluster_score_pallas(q, blocks, sel, interpret=True)
    err = float(jnp.max(jnp.abs(out_k - ref(q, blocks, sel))))
    rows.append({"kernel": "cluster_score", "shape": f"B{B} N{N} cap{cap} d{dim}",
                 "jnp_ref_ms": round(t_ref, 2), "max_err": err,
                 "note": "pallas interpret=True validates; MXU path is the TPU target"})

    B, n, F, H = 64, 32, 21, 32
    x = jnp.asarray(rng.standard_normal((B, n, F)), jnp.float32)
    wx = jnp.asarray(rng.standard_normal((F, 4 * H)) * 0.3, jnp.float32)
    wh = jnp.asarray(rng.standard_normal((H, 4 * H)) * 0.3, jnp.float32)
    b = jnp.zeros(4 * H, jnp.float32)
    ref = jax.jit(lstm_sequence_ref)
    _, t_ref = C.timed(ref, x, wx, wh, b)
    out_k = lstm_sequence_pallas(x, wx, wh, b, interpret=True)
    err = float(jnp.max(jnp.abs(out_k - ref(x, wx, wh, b))))
    rows.append({"kernel": "lstm", "shape": f"B{B} n{n} F{F} H{H}",
                 "jnp_ref_ms": round(t_ref, 2), "max_err": err,
                 "note": "weights VMEM-resident across the whole sequence"})
    return {"table": "kernelbench", "rows": rows}
