"""Paper Table 5: selective retrieval with a high-dimension LLM encoder
(RepLLaMA analogue: 4x the base embedding dim). CluSD's cost scales with
the selected fraction, full dense with the whole corpus."""

import jax

from benchmarks import common as C
from repro.core import clusd as cl


def run():
    rows = []
    for dim, tag in [(48, "base-dim"), (192, "LLM-dim (4x)")]:
        cfg, corpus, index, params, _, _ = C.trained_index(dim=dim)
        index.lstm_params = params
        qs = C.test_queries(corpus, n=128)
        (ids_f, _), lat_f = C.timed(
            jax.jit(lambda q: cl.full_dense_topk(index.embeddings, q, 100)),
            qs.q_dense)
        (ids_c, _, diag), lat_c = C.timed(
            jax.jit(lambda qd, qt, qw: cl.retrieve(cfg, index, qd, qt, qw,
                                                   selector_params=params)),
            qs.q_dense, qs.q_terms, qs.q_weights)
        rows.append({"dim": dim, "tag": tag,
                     "full_MRR@10": C.quality(ids_f, qs)["MRR@10"],
                     "clusd_MRR@10": C.quality(ids_c, qs)["MRR@10"],
                     "full_ms": round(lat_f, 1), "clusd_ms": round(lat_c, 1),
                     "pctD": round(
                         100 * float(diag["frac_docs_scanned"].mean()), 2),
                     "emb_space_mb": round(
                         index.embeddings.size * 4 / 2**20, 1)})
    return {"table": "table5_repllama", "rows": rows}
