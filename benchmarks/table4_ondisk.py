"""Paper Table 4: retrieval with embeddings on disk. Block I/O (CluSD) vs
per-doc random I/O (rerank, graph navigation). Reports measured I/O ops /
bytes plus the paper's latency model (0.15 ms/op + bandwidth).

The CluSD stores are exercised both directly (pack once, reopen read-only)
and through a persistent built index (repro.index): write_index -> mmap
IndexReader -> ShardedDiskStore with coalesced run reads."""

import os
import tempfile

import numpy as np

from benchmarks import common as C
from repro.core import baselines as bl
from repro.core import clusd as cl
from repro.core import disk as dk
from repro.data import mrr_at


def run():
    cfg, corpus, index, params, _, _ = C.trained_index()
    index.lstm_params = params
    qs = C.test_queries(corpus, n=32)
    nq = qs.q_dense.shape[0]
    tmp = tempfile.mkdtemp()
    # pack once (offline), then reopen read-only — the serve-time pattern
    packed = dk.DiskClusterStore.pack(os.path.join(tmp, "blocks.bin"),
                                      corpus.embeddings, index.cluster_docs)
    cstore = dk.DiskClusterStore.open(os.path.join(tmp, "blocks.bin"),
                                      packed.n_clusters, packed.cap,
                                      packed.dim)
    dstore = dk.DiskDocStore(os.path.join(tmp, "docs.bin"), corpus.embeddings)
    rows = []

    ids, _, st = dk.ondisk_rerank_retrieve(cfg, index, dstore, qs.q_dense,
                                           qs.q_terms, qs.q_weights,
                                           depth=cfg.k_sparse)
    rows.append({"method": "S+Rerank (per-doc I/O)",
                 "MRR@10": round(mrr_at(np.asarray(ids), qs.rel_doc), 4),
                 "io_ops_per_q": st.n_ops // nq,
                 "io_mb_per_q": round(st.bytes / nq / 2**20, 3),
                 "model_ms_per_q": round(st.model_ms() / nq, 2),
                 "wall_io_ms_per_q": round(st.wall_ms / nq, 2)})

    # LADR-like on-disk: per-doc reads for every scored candidate
    knn = bl.build_doc_knn(index, n_neighbors=8, probe_clusters=3)
    import jax
    ids, _, d = jax.jit(lambda qd, qt, qw: bl.ladr_retrieve(
        cfg, index, knn, qd, qt, qw, n_seeds=16, depth=2, budget=256))(
        qs.q_dense, qs.q_terms, qs.q_weights)
    n_fetch = min(int(d["n_docs_fetched"]), index.n_docs)
    st_l = dk.IOStats(n_ops=n_fetch * nq,
                      bytes=n_fetch * nq * dstore.doc_bytes)
    rows.append({"method": "S+LADR_fast (per-doc I/O)",
                 "MRR@10": round(mrr_at(np.asarray(ids), qs.rel_doc), 4),
                 "io_ops_per_q": n_fetch,
                 "io_mb_per_q": round(st_l.bytes / nq / 2**20, 3),
                 "model_ms_per_q": round(st_l.model_ms() / nq, 2),
                 "wall_io_ms_per_q": None})

    ids, _, st = dk.ondisk_clusd_retrieve(cfg, index, cstore, qs.q_dense,
                                          qs.q_terms, qs.q_weights)
    rows.append({"method": "S+CluSD (block I/O, batch-dedup)",
                 "MRR@10": round(mrr_at(np.asarray(ids), qs.rel_doc), 4),
                 "io_ops_per_q": st.n_ops // nq,
                 "io_mb_per_q": round(st.bytes / nq / 2**20, 3),
                 "model_ms_per_q": round(st.model_ms() / nq, 2),
                 "wall_io_ms_per_q": round(st.wall_ms / nq, 2)})

    # serving engine on the same store: LRU block cache + Stage-I prefetch
    from repro.engine import DiskStore, RetrievalEngine
    with RetrievalEngine(cfg, index,
                         store=DiskStore(cstore, index.cluster_docs),
                         max_batch=8, cache_capacity=cfg.n_clusters) as eng:
        all_ids = []
        for i in range(0, nq, 8):
            eids, _ = eng.retrieve(qs.q_dense[i:i + 8], qs.q_terms[i:i + 8],
                                   qs.q_weights[i:i + 8])
            all_ids.append(np.asarray(eids))
    es = eng.stats()    # after close(): prefetch drained, counters final
    rows.append({"method": "S+CluSD (engine: cache+prefetch)",
                 "MRR@10": round(mrr_at(np.concatenate(all_ids),
                                        qs.rel_doc), 4),
                 "io_ops_per_q": es["io"]["n_ops"] // nq,
                 "io_mb_per_q": round(es["io"]["bytes"] / nq / 2**20, 3),
                 "model_ms_per_q": round(es["io"]["model_ms"] / nq, 2),
                 "cache_hit_rate": es["cache"]["hit_rate"]})

    # persistent built index: write once, reopen via mmap, serve through
    # the sharded store (coalesced run reads across shard files)
    from repro import index as index_lib
    index_lib.write_index(os.path.join(tmp, "index"), cfg, index,
                          np.asarray(corpus.embeddings), n_shards=4)
    reader = index_lib.IndexReader.open(os.path.join(tmp, "index"),
                                        verify="full")
    lcfg, lindex = reader.load_index()
    with reader.engine(cfg=lcfg, index=lindex, max_batch=8,
                       cache_capacity=cfg.n_clusters) as seng:
        all_ids = []
        for i in range(0, nq, 8):
            eids, _ = seng.retrieve(qs.q_dense[i:i + 8], qs.q_terms[i:i + 8],
                                    qs.q_weights[i:i + 8])
            all_ids.append(np.asarray(eids))
    ss = seng.stats()
    rows.append({"method": "S+CluSD (built index: sharded, coalesced)",
                 "MRR@10": round(mrr_at(np.concatenate(all_ids),
                                        qs.rel_doc), 4),
                 "io_ops_per_q": ss["io"]["n_ops"] // nq,
                 "io_mb_per_q": round(ss["io"]["bytes"] / nq / 2**20, 3),
                 "model_ms_per_q": round(ss["io"]["model_ms"] / nq, 2),
                 "cache_hit_rate": ss["cache"]["hit_rate"]})

    # format-v2 PQ code shards: same engine + selection, uint8 codes off
    # disk (decode-on-fetch ADC) instead of float blocks
    from repro.core import quant as quant_lib
    index.quantizer = quant_lib.train_pq(jax.random.key(3),
                                         corpus.embeddings, 12, rotate=True)
    index_lib.write_index(os.path.join(tmp, "index_pq"), cfg, index,
                          np.asarray(corpus.embeddings), n_shards=4,
                          format_version=index_lib.FORMAT_VERSION_PQ)
    index.quantizer = None
    preader = index_lib.IndexReader.open(os.path.join(tmp, "index_pq"),
                                         verify="full")
    with preader.engine(max_batch=8, cache_capacity=cfg.n_clusters) as peng:
        all_ids = []
        for i in range(0, nq, 8):
            eids, _ = peng.retrieve(qs.q_dense[i:i + 8], qs.q_terms[i:i + 8],
                                    qs.q_weights[i:i + 8])
            all_ids.append(np.asarray(eids))
    ps = peng.stats()
    rows.append({"method": "S+CluSD (PQ v2 index: code shards, ADC)",
                 "MRR@10": round(mrr_at(np.concatenate(all_ids),
                                        qs.rel_doc), 4),
                 "io_ops_per_q": ps["io"]["n_ops"] // nq,
                 "io_mb_per_q": round(ps["io"]["bytes"] / nq / 2**20, 3),
                 "model_ms_per_q": round(ps["io"]["model_ms"] / nq, 2),
                 "cache_hit_rate": ps["cache"]["hit_rate"]})
    return {"table": "table4_ondisk", "rows": rows}
