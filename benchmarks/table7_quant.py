"""Paper Table 7: CluSD with different quantizers (PQ / OPQ-rotated PQ /
coarser PQ — DistillVQ & JPQ stand-ins) vs IVF 2% under each quantizer."""

import jax

from benchmarks import common as C
from repro.core import baselines as bl
from repro.core import clusd as cl
from repro.core import quant as qt


def run():
    cfg, corpus, index, params, _, _ = C.trained_index()
    index.lstm_params = params
    qs = C.test_queries(corpus, n=192)
    rows = []
    for nsub, rotate, tag in [(8, False, "PQ m=8"),
                              (8, True, "OPQ m=8 (DistillVQ-like)"),
                              (4, False, "PQ m=4 (JPQ-like)")]:
        pq = qt.train_pq(jax.random.key(3), corpus.embeddings, nsub=nsub,
                         iters=6, rotate=rotate)
        index.quantizer = pq
        n_probe = max(1, int(cfg.n_clusters * 0.02))
        ids_i, _, _ = jax.jit(lambda qd, qt_, qw: bl.ivf_retrieve(
            cfg, index, qd, qt_, qw, n_probe))(
            qs.q_dense, qs.q_terms, qs.q_weights)
        ids_c, _, _ = jax.jit(lambda qd, qt_, qw: cl.retrieve(
            cfg, index, qd, qt_, qw, selector_params=params))(
            qs.q_dense, qs.q_terms, qs.q_weights)
        rows.append({"quantizer": tag,
                     "space_mb": round(pq.space_bytes() / 2**20, 2),
                     "S+IVF2%_MRR@10": C.quality(ids_i, qs)["MRR@10"],
                     "S+CluSD_MRR@10": C.quality(ids_c, qs)["MRR@10"],
                     "S+CluSD_R@100": C.quality(ids_c, qs)["R@100"]})
    index.quantizer = None
    return {"table": "table7_quant", "rows": rows}
