"""Shared benchmark fixture: one synthetic corpus + CluSD index + trained
selectors, cached at module scope. Sizes scale with BENCH_SCALE (default
CPU-friendly; the benchmark *structure* matches the paper's MS MARCO setup,
the absolute numbers are synthetic-corpus analogues — see EXPERIMENTS.md)."""

import dataclasses
import functools
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_config
from repro.core import clusd as cl
from repro.core import train_lstm as tl
from repro.data import mrr_at, recall_at, synth_corpus, synth_queries

SCALE = float(os.environ.get("BENCH_SCALE", "1.0"))


def git_sha():
    """Current commit (short), or "unknown" outside a git checkout."""
    import subprocess
    try:
        return subprocess.check_output(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            stderr=subprocess.DEVNULL).decode().strip()
    except Exception:
        return "unknown"


def bench_host():
    """Hardware stamp: absolute latencies are only comparable between runs
    on matching hosts (check_regression skips the latency gate otherwise;
    size and quality gates are hardware-independent and always apply)."""
    import platform
    return {"machine": platform.machine(), "system": platform.system(),
            "cpus": os.cpu_count()}


def bench_meta(cfg):
    """Stamp for BENCH_*.json files so the perf trajectory in ROADMAP stays
    comparable across PRs: what commit, host, and index geometry produced
    these numbers."""
    return {"git_sha": git_sha(), "host": bench_host(),
            "config": {"n_docs": cfg.n_docs, "n_clusters": cfg.n_clusters,
                       "dim": cfg.dim, "cluster_cap": cfg.cluster_cap,
                       "dtype": cfg.dtype}}


def bench_cfg(n_clusters=None, dim=None):
    return dataclasses.replace(
        get_config("clusd-msmarco", "smoke"),
        n_docs=int(24000 * SCALE), dim=dim or 48, vocab=2048,
        n_clusters=n_clusters or 256,
        max_postings=1024, doc_terms=16,
        k_sparse=512, bins=(10, 25, 50, 100, 200, 512),
        n_candidates=32, u_bins=6, lstm_hidden=32, n_neighbors=64,
        theta=0.02, max_selected=16, alpha=0.5, k_final=512,
        train_queries=int(768 * SCALE), epochs=30)


@functools.lru_cache(maxsize=4)
def corpus_and_index(n_clusters=256, dim=48, seed=0):
    cfg = bench_cfg(n_clusters, dim)
    corpus = synth_corpus(seed, cfg.n_docs, cfg.dim, cfg.vocab,
                          topic_noise=0.5)
    index = cl.build_index(cfg, jax.random.key(seed), corpus.embeddings,
                           corpus.doc_terms, corpus.doc_weights)
    return cfg, corpus, index


@functools.lru_cache(maxsize=4)
def trained_index(n_clusters=256, dim=48, selector="lstm", seed=0):
    cfg, corpus, index = corpus_and_index(n_clusters, dim, seed)
    tq = synth_queries(1, corpus, cfg.train_queries)
    _, feats, labels = tl.make_labels(cfg, index, tq.q_dense, tq.q_terms,
                                      tq.q_weights)
    params, hist = tl.train_selector(cfg, jax.random.key(2),
                                     np.asarray(feats), np.asarray(labels),
                                     selector=selector)
    return cfg, corpus, index, params, (np.asarray(feats),
                                        np.asarray(labels)), hist


def test_queries(corpus, n=256, seed=9):
    # dense/sparse noise chosen so neither retriever saturates (paper regime:
    # dense MRR ~ sparse MRR, fusion clearly better than both)
    return synth_queries(seed, corpus, int(n * max(SCALE, 0.25)),
                         dense_noise=0.30, term_noise_frac=0.4)


def timed(fn, *args, reps=3):
    fn(*args)  # compile
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return out, float(np.median(ts)) * 1e3


def quality(ids, qs, k_final=512):
    return {"MRR@10": round(mrr_at(np.asarray(ids), qs.rel_doc), 4),
            "R@100": round(recall_at(np.asarray(ids), qs.rel_doc, 100), 4)}


def tune_theta(cfg, index, params, feats, target_avg):
    """Match the paper's Table-8 protocol: pick theta so the average number
    of selected clusters hits a target."""
    from repro.core.lstm import SELECTORS
    import jax.numpy as jnp
    _, apply = SELECTORS["lstm"]
    probs = np.asarray(apply(params, jnp.asarray(feats)))
    lo, hi = 0.0, 1.0
    for _ in range(30):
        mid = (lo + hi) / 2
        avg = (probs >= mid).sum(1).mean()
        if avg > target_avg:
            lo = mid
        else:
            hi = mid
    return (lo + hi) / 2
