"""Benchmark regression guard: compare freshly generated BENCH_serve.json /
BENCH_index.json / BENCH_train.json against the committed baseline and
fail on

  * >20% serving latency regression (p50 batch ms, per backend row) or
    >20% steady-QPS drop,
  * index-size growth of >20% without a format-version bump
    (`max_format_version` in BENCH_index.json is the bump signal),
  * MRR@10 drift beyond 0.02 on any matched serve row (quality is part of
    the contract, not just speed),
  * calibrated selector recall@budget (BENCH_train.json) dropping more
    than 0.02 below the baseline — recall is hardware-independent, so
    like the MRR gate it stays active across host-stamp mismatches
    (geometry must still match),
  * the intra-file ADC invariant: within the FRESH BENCH_serve.json the
    pq-sharded (ADC-served) p50 must stay below the in-memory p50. Both
    rows come from the same run on the same host, so this gate never
    skips on host/geometry mismatch — it guards the point of the
    ADC+fused-tail serving path absolutely, not relative to a baseline,
  * the intra-file tracing-overhead gate: the tracing-enabled p50 in the
    pq-sharded row's `trace_overhead` pair must stay within 5% (+0.2ms
    timer-noise floor) of the tracing-disabled p50 measured by the same
    engine in the same run (repro.obs spans must stay near-free),
  * the intra-file hybrid gate: within the FRESH BENCH_train.json the
    hybrid operating point (RRF fusion + neighbor-graph expansion) must
    reach at least the baseline calibration's recall@budget at no more
    est_read_bytes, and land strictly above the baseline (depth-0)
    stage-1 ceiling — expansion exists to buy recall at the same block
    I/O bill, so both rows come from the same run and the gate never
    skips on host/geometry mismatch,
  * the intra-file router-scaling gate: within the FRESH
    BENCH_serve.json's `router_scaling` section the 3-host scatter-gather
    QPS must reach >=1.8x the 1-host QPS (same run, same simulated
    per-host I/O service time, so the ratio is hardware-independent), and
    no router row may report failed or degraded requests. Skipped with a
    note when the section is absent (pre-router BENCH files),
  * the intra-file churn-soak gate (BENCH_soak.json, --fresh-soak): the
    soak run must report failed_requests == 0, an SLO verdict that never
    paged, a measured p99 within the gate the run itself declared
    (p99_gate_ms), and all in-run /metrics + /healthz scrapes returning
    200. Baseline-free — the file is self-judging via the SLOMonitor —
    and skipped with a note when absent.

Intended CI wiring (see .github/workflows/ci.yml) — the baseline comes
from the PR's MERGE BASE, not HEAD, so a PR that restamps its own BENCH
files cannot launder a regression past the gate:

  BASE=$(git merge-base HEAD origin/main)   # or the PR base SHA
  git show $BASE:BENCH_serve.json > /tmp/base_serve.json
  git show $BASE:BENCH_index.json > /tmp/base_index.json
  PYTHONPATH=src python -m benchmarks.serve_engine
  PYTHONPATH=src python -m benchmarks.build_index
  PYTHONPATH=src python -m benchmarks.check_regression \
      --baseline-serve /tmp/base_serve.json \
      --baseline-index /tmp/base_index.json

Exit code 0 = within budget; 1 = regression (each violation printed).
New rows/backends in the fresh files are informational only — the gate
covers rows present in BOTH files, so adding a backend never fails the
guard; geometry changes skip the latency/size gates (stamped config must
match); a host stamp mismatch (baseline measured on different hardware)
skips the latency gate but keeps the hardware-independent MRR and size
gates active.
"""

import argparse
import json
import os
import sys

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _load(path):
    with open(path) as f:
        return json.load(f)


def _rows_by_backend(serve):
    return {r["backend"]: r for r in serve.get("rows", [])}


def check_train(baseline_train, fresh_train, recall_tol=0.02):
    """BENCH_train.json gate: calibrated recall@budget must not drift more
    than `recall_tol` below the merge-base baseline. Skipped (with a note)
    when either side lacks the file/field — a new row is informational,
    same as a new serve backend. Same host-stamp rule as MRR: recall is
    hardware-independent, so only a geometry change skips the gate."""
    bad = []
    base = (baseline_train or {}).get("recall_at_budget")
    fresh = (fresh_train or {}).get("recall_at_budget")
    if base is None or fresh is None:
        print("note: BENCH_train.json missing on one side; "
              "recall@budget gate skipped")
        return bad
    if (baseline_train or {}).get("config") != \
            (fresh_train or {}).get("config"):
        print("note: train bench geometry changed; recall@budget gate "
              "skipped")
        return bad
    if fresh < base - recall_tol:
        bad.append(f"[train] recall@budget {fresh:.4f} < "
                   f"{base:.4f} - {recall_tol}")
    return bad


def check_intra_train(fresh_train):
    """Baseline-free invariants over the fresh train bench alone: the
    hybrid (RRF + expansion) operating point must beat what it replaces —
    recall@budget(hybrid) >= recall@budget(baseline calibration) at
    est_read_bytes(hybrid) <= est_read_bytes(baseline), and strictly above
    the baseline stage-1 ceiling (otherwise expansion bought nothing the
    old candidate list didn't already hold). Both sections come from the
    same run, so no host/geometry skip applies; only files predating the
    hybrid section skip (with a note)."""
    bad = []
    cal = (fresh_train or {}).get("calibration") or {}
    hyb = (fresh_train or {}).get("hybrid") or {}
    if not cal or not hyb:
        print("note: calibration/hybrid section missing from "
              "BENCH_train.json; intra-train hybrid gate skipped")
        return bad
    base_rec, hyb_rec = cal.get("recall_at_budget"), hyb.get("recall_at_budget")
    if base_rec is not None and hyb_rec is not None and hyb_rec < base_rec:
        bad.append(f"[train:intra] hybrid recall@budget {hyb_rec:.4f} < "
                   f"baseline {base_rec:.4f} (hybrid candidates must win)")
    ceiling = cal.get("stage1_ceiling")
    if ceiling is not None and hyb_rec is not None and hyb_rec <= ceiling:
        bad.append(f"[train:intra] hybrid recall@budget {hyb_rec:.4f} <= "
                   f"baseline stage-1 ceiling {ceiling:.4f} (expansion "
                   f"must raise the ceiling, not just fill it)")
    base_b, hyb_b = cal.get("est_read_bytes"), hyb.get("est_read_bytes")
    if base_b and hyb_b and hyb_b > base_b:
        bad.append(f"[train:intra] hybrid est_read_bytes {hyb_b} > "
                   f"baseline {base_b} (recall must come at the same "
                   f"I/O budget)")
    return bad


def check_intra_serve(fresh_serve):
    """Baseline-free invariants over the fresh serve table alone. The
    pq-sharded backend is served via in-kernel ADC + the fused
    score->fuse->top-k tail; if it cannot beat the in-memory float
    backend measured in the SAME run, the fast path has regressed no
    matter what the merge-base says. Skipped only when either row is
    absent (older BENCH files)."""
    bad = []
    rows = _rows_by_backend(fresh_serve)
    pq, mem = rows.get("pq-sharded (v2 index)"), rows.get("in-memory")
    if not pq or not mem:
        print("note: pq-sharded/in-memory row missing; intra-serve ADC "
              "gate skipped")
        return bad
    pp50, mp50 = pq.get("p50_batch_ms"), mem.get("p50_batch_ms")
    if pp50 and mp50 and pp50 >= mp50:
        bad.append(f"[serve:intra] pq-sharded p50 {pp50:.2f}ms >= "
                   f"in-memory p50 {mp50:.2f}ms (ADC fast path must win)")
    if pq.get("use_adc") is False:
        bad.append("[serve:intra] pq-sharded row served without ADC")
    dm = pq.get("decode_ms")
    if dm is not None and dm != 0.0:
        bad.append(f"[serve:intra] ADC path decoded floats on the host "
                   f"(decode_ms={dm})")
    # tracing-overhead gate: both p50s come from the same engine in the
    # same run (serve_engine.py passes 1 and 2), so this never skips on a
    # host mismatch. The 0.2ms absolute floor guards against timer noise
    # dominating the ratio on sub-millisecond batches.
    ov = pq.get("trace_overhead")
    if ov:
        off, on = ov.get("p50_ms_untraced"), ov.get("p50_ms_traced")
        if off and on and on > off * 1.05 + 0.2:
            bad.append(f"[serve:intra] tracing-enabled p50 {on:.2f}ms "
                       f"exceeds 1.05x untraced p50 {off:.2f}ms (+0.2ms "
                       f"noise floor): span overhead regressed")
    else:
        print("note: trace_overhead missing from pq-sharded row; tracing "
              "overhead gate skipped")
    return bad


def check_intra_router(fresh_serve):
    """Baseline-free gates over the router_scaling section of the fresh
    serve table. Both rows come from the SAME run on the SAME box with the
    same simulated per-host I/O service time, so the 3-host/1-host QPS
    ratio is hardware-independent: it measures whether scatter-gather
    actually overlaps the per-host fetches. Skipped (with a note) when the
    section is absent — older BENCH files predate the router."""
    bad = []
    section = fresh_serve.get("router_scaling")
    if not section:
        print("note: router_scaling missing from serve table; router "
              "scaling gate skipped")
        return bad
    by_hosts = {r.get("hosts"): r for r in section
                if r.get("replication") == 1}
    one, three = by_hosts.get(1), by_hosts.get(3)
    if one and three:
        q1, q3 = one.get("qps_total"), three.get("qps_total")
        if q1 and q3 and q3 < 1.8 * q1:
            bad.append(f"[serve:router] 3-host QPS {q3:.1f} < 1.8x "
                       f"1-host QPS {q1:.1f} (scatter-gather no longer "
                       f"overlaps per-host I/O)")
    else:
        print("note: router_scaling lacks 1-host/3-host rows; scaling "
              "ratio gate skipped")
    for r in section:
        name = r.get("backend", "?")
        if r.get("failed_requests"):
            bad.append(f"[serve:router] {name} failed_requests="
                       f"{r['failed_requests']} (must be 0)")
        if r.get("degraded_requests"):
            bad.append(f"[serve:router] {name} degraded_requests="
                       f"{r['degraded_requests']} (replicas must cover "
                       f"every shard in these rows)")
    return bad


def check_intra_soak(fresh_soak):
    """Baseline-free gates over BENCH_soak.json (benchmarks/soak.py): the
    churn soak is self-judging — the file records the SLOMonitor's own
    verdict and the p99 gate the run declared, so the check needs no
    merge-base copy. Fails when any request failed during churn, when the
    SLO ever paged (verdict.ok is False or final state == PAGE), when the
    measured p99 exceeds the recorded gate, or when any in-run endpoint
    scrape returned non-200. Skipped (with a note) when the file is
    absent — older checkouts predate the soak."""
    bad = []
    if not fresh_soak:
        print("note: BENCH_soak.json missing; churn-soak gate skipped")
        return bad
    failed = fresh_soak.get("failed_requests")
    if failed:
        bad.append(f"[soak] failed_requests={failed} (must be 0); "
                   f"errors: {fresh_soak.get('load_errors')}")
    slo = fresh_soak.get("slo") or {}
    verdict = slo.get("verdict") or {}
    if slo.get("final_state") == "PAGE" or verdict.get("ok") is False:
        bad.append(f"[soak] SLO paged: final_state="
                   f"{slo.get('final_state')}, verdict={verdict}")
    p99, gate = fresh_soak.get("p99_ms"), fresh_soak.get("p99_gate_ms")
    if p99 is not None and gate is not None and p99 > gate:
        bad.append(f"[soak] p99 {p99:.2f}ms > declared gate {gate:.2f}ms")
    for s in fresh_soak.get("scrapes", []):
        if s.get("status") != 200:
            bad.append(f"[soak] scrape {s.get('path')} returned "
                       f"{s.get('status')} (endpoints must stay live "
                       f"through churn)")
    return bad


def check(baseline_serve, fresh_serve, baseline_index, fresh_index,
          tol=0.20, mrr_tol=0.02, size_tol=0.20):
    """Returns a list of violation strings (empty = pass)."""
    bad = []

    def geometry(d):
        return d.get("config", {})

    def host(d):
        return d.get("host")

    if geometry(baseline_serve) != geometry(fresh_serve):
        # different corpus/geometry: latency numbers aren't comparable;
        # report nothing but say so loudly
        print("note: serve geometry changed "
              f"({geometry(baseline_serve)} -> {geometry(fresh_serve)}); "
              "latency gate skipped")
    elif host(baseline_serve) is not None and \
            host(baseline_serve) != host(fresh_serve):
        # absolute latencies measured on different hardware aren't
        # comparable (dev laptop vs CI runner); quality gates below still
        # apply because MRR is hardware-independent
        print(f"note: serve host changed ({host(baseline_serve)} -> "
              f"{host(fresh_serve)}); latency gate skipped, "
              "MRR gate still active")
        base_rows = _rows_by_backend(baseline_serve)
        fresh_rows = _rows_by_backend(fresh_serve)
        for name in sorted(set(base_rows) & set(fresh_rows)):
            bm = base_rows[name].get("MRR@10")
            fm = fresh_rows[name].get("MRR@10")
            if bm is not None and fm is not None and fm < bm - mrr_tol:
                bad.append(f"[serve:{name}] MRR@10 {fm:.4f} < "
                           f"{bm:.4f} - {mrr_tol}")
    else:
        base_rows = _rows_by_backend(baseline_serve)
        fresh_rows = _rows_by_backend(fresh_serve)
        for name in sorted(set(base_rows) & set(fresh_rows)):
            b, f = base_rows[name], fresh_rows[name]
            bp50, fp50 = b.get("p50_batch_ms"), f.get("p50_batch_ms")
            if bp50 and fp50 and fp50 > bp50 * (1 + tol):
                bad.append(f"[serve:{name}] p50 {fp50:.2f}ms > "
                           f"{bp50:.2f}ms * {1 + tol:.2f}")
            bq, fq = b.get("qps_steady"), f.get("qps_steady")
            if bq and fq and fq < bq / (1 + tol):
                bad.append(f"[serve:{name}] steady QPS {fq:.1f} < "
                           f"{bq:.1f} / {1 + tol:.2f}")
            bm, fm = b.get("MRR@10"), f.get("MRR@10")
            if bm is not None and fm is not None and fm < bm - mrr_tol:
                bad.append(f"[serve:{name}] MRR@10 {fm:.4f} < "
                           f"{bm:.4f} - {mrr_tol}")

    if geometry(baseline_index) != geometry(fresh_index):
        print("note: index geometry changed; size gate skipped")
    else:
        bver = baseline_index.get("max_format_version", 1)
        fver = fresh_index.get("max_format_version", 1)
        for key, label in (("index_bytes", "v1 index"),):
            bb, fb = baseline_index.get(key), fresh_index.get(key)
            if bb and fb and fb > bb * (1 + size_tol) and fver <= bver:
                bad.append(f"[index] {label} grew {bb} -> {fb} bytes "
                           f"(> {1 + size_tol:.2f}x) without a "
                           f"format-version bump ({bver} -> {fver})")
        bpq = (baseline_index.get("pq") or {}).get("index_bytes")
        fpq = (fresh_index.get("pq") or {}).get("index_bytes")
        if bpq and fpq and fpq > bpq * (1 + size_tol) and fver <= bver:
            bad.append(f"[index] pq index grew {bpq} -> {fpq} bytes "
                       f"without a format-version bump")
        fratio = (fresh_index.get("pq") or {}).get("size_ratio_vs_v1")
        if fratio is not None and fratio < 4.0:
            bad.append(f"[index] pq size_ratio_vs_v1 {fratio} < 4.0 "
                       f"(acceptance floor)")
    return bad


def _load_optional(path):
    if not path or not os.path.isfile(path):
        return {}
    try:
        return _load(path)
    except (OSError, ValueError):
        return {}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline-serve", required=True)
    ap.add_argument("--baseline-index", required=True)
    ap.add_argument("--baseline-train", default=None,
                    help="merge-base BENCH_train.json (optional: the gate "
                         "skips when absent/empty, so the first PR landing "
                         "the train bench passes)")
    ap.add_argument("--fresh-serve",
                    default=os.path.join(REPO_ROOT, "BENCH_serve.json"))
    ap.add_argument("--fresh-index",
                    default=os.path.join(REPO_ROOT, "BENCH_index.json"))
    ap.add_argument("--fresh-train",
                    default=os.path.join(REPO_ROOT, "BENCH_train.json"))
    ap.add_argument("--fresh-soak",
                    default=os.path.join(REPO_ROOT, "BENCH_soak.json"),
                    help="BENCH_soak.json from benchmarks/soak.py; the "
                         "gate is baseline-free (the file carries its own "
                         "SLO verdict) and skips when the file is absent")
    ap.add_argument("--tol", type=float,
                    default=float(os.environ.get("BENCH_REGRESSION_TOL",
                                                 "0.20")),
                    help="fractional latency budget (default 20%%)")
    ap.add_argument("--size-tol", type=float, default=0.20,
                    help="index-size growth budget; NOT loosened by "
                         "BENCH_REGRESSION_TOL (size is deterministic)")
    ap.add_argument("--mrr-tol", type=float, default=0.02)
    args = ap.parse_args(argv)

    bad = check(_load(args.baseline_serve), _load(args.fresh_serve),
                _load(args.baseline_index), _load(args.fresh_index),
                tol=args.tol, mrr_tol=args.mrr_tol, size_tol=args.size_tol)
    bad += check_train(_load_optional(args.baseline_train),
                       _load_optional(args.fresh_train),
                       recall_tol=args.mrr_tol)
    bad += check_intra_train(_load_optional(args.fresh_train))
    bad += check_intra_serve(_load(args.fresh_serve))
    bad += check_intra_router(_load(args.fresh_serve))
    bad += check_intra_soak(_load_optional(args.fresh_soak))
    if bad:
        print("BENCH REGRESSION:")
        for line in bad:
            print("  " + line)
        return 1
    print(f"bench regression check OK (tol {args.tol:.0%})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
