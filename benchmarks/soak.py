"""Churn soak harness: sustained serving under live index churn, judged
by the SLOMonitor -> BENCH_soak.json (seeds ROADMAP item 4).

A load-generator thread replays the bench query set through a serving
engine (`IndexReader.engine`) for --duration seconds while the main
thread applies --generations rounds of churn through the atomic hot-
reload path: a synthetic upsert/delete delta (`write_index_delta` +
`engine.reload_index`), a selector republish (`publish_selector` +
`engine.reload_selector`), and a final `compact_index` + reload. The
whole run is scored by `repro.obs.SLOMonitor` over the engine's own
MetricsRegistry — the soak maintains three soak.* metrics the default
objectives read:

  soak.requests / soak.failed_requests   counters, one per retrieve call
  soak.recall_drift                      gauge: baseline MRR@10 minus the
                                         latest pass's MRR@10, masked to
                                         queries whose relevant doc is
                                         still alive (deletes excluded)

plus the engine's serve.batch_ms histogram for the p99 objective. A
MetricsExporter serves /metrics + /healthz throughout and the harness
scrapes both mid-run (statuses recorded in the output; any non-200 fails
the run).

BENCH_soak.json is self-describing: it records the p99 gate it ran
against and the SLOMonitor's own verdict, so `check_regression.py
--fresh-soak` gates it (failed_requests == 0, final state != PAGE,
measured p99 <= gate) without a baseline file. Field docs:
docs/BENCHMARKS.md.

Usage (the index must be built with a trained selector, e.g.
`python -m repro.launch.build_index ... --train-queries N`):
  PYTHONPATH=src python -m benchmarks.soak --index-dir /tmp/idx \
      [--duration 30] [--generations 2] [--queries 64] [--batch 16] \
      [--upserts 24] [--deletes 8] [--p99-gate-ms 500] \
      [--drift-gate 0.1] [--out BENCH_soak.json] [--seed 0]
"""

import argparse
import dataclasses
import json
import os
import sys
import threading
import time
import urllib.request

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from benchmarks import common as C


def _scrape(port, path):
    url = f"http://127.0.0.1:{port}{path}"
    try:
        with urllib.request.urlopen(url, timeout=5) as r:
            return {"path": path, "status": r.status,
                    "bytes": len(r.read())}
    except urllib.error.HTTPError as e:
        return {"path": path, "status": e.code, "bytes": 0}
    except Exception as e:
        return {"path": path, "status": -1, "error": repr(e)}


class _LoadGen(threading.Thread):
    """Replays the query set until stopped; every retrieve call counts in
    soak.requests, every exception in soak.failed_requests; per-pass
    MRR@10 feeds the soak.recall_drift gauge."""

    def __init__(self, engine, reader, test_q, batch):
        super().__init__(daemon=True)
        self.engine, self.reader = engine, reader
        self.q, self.batch = test_q, int(batch)
        self.stop = threading.Event()
        self.requests = engine.metrics.counter("soak.requests")
        self.failed = engine.metrics.counter("soak.failed_requests")
        self.drift = engine.metrics.gauge("soak.recall_drift")
        self.baseline_mrr = None
        self.last_mrr = None
        self.max_drift = 0.0
        self.passes = 0
        self.errors = []

    def _pass_mrr(self, ids):
        """MRR@10 over queries whose relevant doc is still alive (churn
        deletes docs; a deleted relevant doc is a corpus change, not a
        serving regression)."""
        rel = np.asarray(self.q.rel_doc[:ids.shape[0]])
        try:
            dc = np.asarray(self.reader.array("doc_cluster"))
            alive = (rel < len(dc)) & (dc[np.minimum(rel, len(dc) - 1)] >= 0)
        except Exception:
            alive = np.ones(len(rel), bool)
        if not alive.any():
            return None
        return C.mrr_at(ids[alive], rel[alive])

    def run(self):
        n = int(self.q.q_dense.shape[0])
        while not self.stop.is_set():
            ids = []
            for i in range(0, n, self.batch):
                if self.stop.is_set():
                    return
                try:
                    out, _ = self.engine.retrieve(
                        self.q.q_dense[i:i + self.batch],
                        self.q.q_terms[i:i + self.batch],
                        self.q.q_weights[i:i + self.batch])
                    ids.append(np.asarray(out))
                    self.requests.inc()
                except Exception as e:
                    self.failed.inc()
                    if len(self.errors) < 8:
                        self.errors.append(repr(e))
            if not ids:
                continue
            mrr = self._pass_mrr(np.concatenate(ids))
            self.passes += 1
            if mrr is None:
                continue
            self.last_mrr = float(mrr)
            if self.baseline_mrr is None:
                self.baseline_mrr = self.last_mrr
            d = max(0.0, self.baseline_mrr - self.last_mrr)
            self.max_drift = max(self.max_drift, d)
            self.drift.set(round(d, 6))


def _churn_round(reader, engine, index_dir, g, args):
    """One generation of churn through the atomic hot-reload path:
    delta -> reload_index, selector republish -> reload_selector."""
    from repro import index as index_lib
    from repro.launch.update_index import synth_delta
    from repro.train import publish_selector

    t0 = time.perf_counter()
    delta, _info = synth_delta(reader, args.upserts, args.deletes,
                               seed=args.seed + 101 * (g + 1))
    index_lib.write_index_delta(index_dir, delta)
    gen_after_delta = engine.reload_index()
    publish_selector(index_dir, reader.lstm_params(),
                     theta=float(engine.cfg.theta),
                     budget=int(engine.cfg.max_selected), verify="none")
    gen_after_pub = engine.reload_selector()
    return {"round": g, "upserts": args.upserts, "deletes": args.deletes,
            "generation_after_delta": int(gen_after_delta),
            "generation_after_publish": int(gen_after_pub),
            "churn_ms": round((time.perf_counter() - t0) * 1e3, 1)}


def main():
    ap = argparse.ArgumentParser(
        description="Churn soak: sustained serving + live index churn "
                    "judged by the SLOMonitor.",
        epilog=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--index-dir", required=True,
                    help="built index with a trained selector "
                         "(repro.launch.build_index --train-queries N)")
    ap.add_argument("--duration", type=float, default=30.0,
                    help="soak wall-clock seconds (churn rounds are "
                         "spread across it)")
    ap.add_argument("--generations", type=int, default=2,
                    help="churn rounds (delta + selector republish each; "
                         "a final compact + reload always runs)")
    ap.add_argument("--queries", type=int, default=64)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--upserts", type=int, default=24,
                    help="docs upserted per churn round")
    ap.add_argument("--deletes", type=int, default=8,
                    help="docs deleted per churn round")
    ap.add_argument("--p99-gate-ms", type=float, default=500.0,
                    help="p99 latency objective for serve.batch_ms; "
                         "recorded in BENCH_soak.json as the documented "
                         "gate check_regression enforces")
    ap.add_argument("--drift-gate", type=float, default=0.1,
                    help="recall-proxy drift objective (absolute MRR@10 "
                         "drop vs the first pass)")
    ap.add_argument("--out", default="BENCH_soak.json")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro import index as index_lib
    from repro.data import synth_corpus, synth_queries
    from repro.obs import MetricsExporter, SLOMonitor, default_objectives

    reader = index_lib.IndexReader.open(args.index_dir, verify="size")
    meta = reader.manifest.get("extra", {}).get("corpus")
    if meta is None or meta.get("kind") != "synthetic":
        raise SystemExit("index lacks synthetic-corpus metadata; the soak "
                         "regenerates its query set from the manifest")
    corpus = synth_corpus(meta["seed"], meta["n_docs"], meta["dim"],
                          meta["vocab"])
    test_q = synth_queries(9, corpus, args.queries)

    with reader.engine(max_batch=args.batch) as engine:
        # SLO windows scale with the run so a sustained regression pages
        # within the soak but one slow batch cannot
        fast = max(1.0, args.duration / 8)
        slow = max(2.0, args.duration / 3)
        objectives = default_objectives(
            p99_gate_ms=args.p99_gate_ms, failure_budget=0.0,
            drift_gate=args.drift_gate, fast_window_s=fast,
            slow_window_s=slow)
        slo = SLOMonitor(engine.metrics, objectives)
        gen = _LoadGen(engine, reader, test_q, args.batch)
        scrapes = []
        churn = []
        t_start = time.perf_counter()
        with MetricsExporter(engine, port=0, slo=slo) as exp:
            print(f"soak: {args.duration:.0f}s, {args.generations} churn "
                  f"round(s), endpoints on port {exp.port}", flush=True)
            gen.start()
            deadline = time.monotonic() + args.duration
            # churn rounds at evenly spaced points inside the window
            marks = [time.monotonic()
                     + args.duration * (g + 1) / (args.generations + 2)
                     for g in range(args.generations)]
            compact_mark = time.monotonic() \
                + args.duration * (args.generations + 1) \
                / (args.generations + 2)
            compacted = False
            g = 0
            while time.monotonic() < deadline:
                slo.evaluate()
                now = time.monotonic()
                if g < len(marks) and now >= marks[g]:
                    churn.append(_churn_round(reader, engine,
                                              args.index_dir, g, args))
                    print(f"churn round {g}: {churn[-1]}", flush=True)
                    # scrape mid-churn: endpoints must answer while
                    # generations roll
                    scrapes.append(_scrape(exp.port, "/metrics"))
                    scrapes.append(_scrape(exp.port, "/healthz"))
                    g += 1
                elif not compacted and now >= compact_mark:
                    t0 = time.perf_counter()
                    index_lib.compact_index(args.index_dir)
                    engine.reload_index()
                    churn.append({"round": "compact",
                                  "churn_ms": round(
                                      (time.perf_counter() - t0) * 1e3, 1)})
                    print(f"compacted + reloaded: {churn[-1]}", flush=True)
                    compacted = True
                time.sleep(min(0.25, max(0.0, deadline - now)))
            scrapes.append(_scrape(exp.port, "/metrics"))
            scrapes.append(_scrape(exp.port, "/metrics.json"))
            scrapes.append(_scrape(exp.port, "/slo"))
            scrapes.append(_scrape(exp.port, "/healthz"))
            gen.stop.set()
            gen.join(timeout=60)
            slo.evaluate()
        wall_s = time.perf_counter() - t_start

        bad_scrapes = [s for s in scrapes if s["status"] != 200]
        if bad_scrapes:
            print(f"SOAK FAIL: non-200 scrapes: {bad_scrapes}")
        lat = engine.serve_stats.latency_percentiles()
        verdict = slo.verdict()
        requests = int(gen.requests.value)
        out = {
            **C.bench_meta(engine.cfg),
            "duration_s": round(wall_s, 1),
            "generations": args.generations,
            "queries_per_pass": args.queries,
            "batch": args.batch,
            "passes": gen.passes,
            "requests": requests,
            "failed_requests": int(gen.failed.value),
            "load_errors": gen.errors,
            "qps": round(requests * args.batch / wall_s, 1),
            "p50_ms": lat.get("p50_ms"),
            "p99_ms": lat.get("p99_ms"),
            "p99_gate_ms": args.p99_gate_ms,
            "drift_gate": args.drift_gate,
            "recall_proxy": {
                "baseline_mrr10": gen.baseline_mrr,
                "final_mrr10": gen.last_mrr,
                "max_drift": round(gen.max_drift, 6),
            },
            "churn": churn,
            "reloads": engine.serve_stats.reloads,
            "selector_reloads": engine.serve_stats.selector_reloads,
            "scrapes": scrapes,
            "slo": {
                "objectives": [dataclasses.asdict(o) for o in objectives],
                "verdict": verdict,
                "final_state": verdict["final_state"],
                "events": list(slo.events)[-20:],
            },
        }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
    print(f"soak -> {args.out}: {requests} request(s) "
          f"({gen.passes} pass(es)), failed={out['failed_requests']}, "
          f"p99={out['p99_ms']}ms (gate {args.p99_gate_ms}ms), "
          f"SLO {verdict['final_state']} "
          f"(pages={verdict['pages']}, warns={verdict['warns']})")
    ok = (not bad_scrapes and out["failed_requests"] == 0
          and verdict["ok"]
          and (out["p99_ms"] is None
               or out["p99_ms"] <= args.p99_gate_ms))
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
