"""Benchmark harness: one module per paper table/figure + the roofline
report. `PYTHONPATH=src python -m benchmarks.run [--only tableX]`.

Results are printed and written to benchmarks/results.json. Absolute
latencies are CPU-container values (single thread); the retrieval QUALITY
relations and the I/O-op accounting are the paper-comparable quantities —
EXPERIMENTS.md maps each table to the paper's claims.
"""

import argparse
import importlib
import json
import os
import sys
import time
import traceback

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

MODULES = [
    "benchmarks.table1_inmemory",
    "benchmarks.table2_graphnav",
    "benchmarks.table4_ondisk",
    "benchmarks.table5_repllama",
    "benchmarks.table6_sparse_models",
    "benchmarks.table7_quant",
    "benchmarks.table8_ablation",
    "benchmarks.serve_engine",
    "benchmarks.build_index",
    "benchmarks.fig2_nclusters",
    "benchmarks.kernelbench",
    "benchmarks.roofline_report",
]


def _print_rows(res):
    rows = res.get("rows") or []
    for r in rows:
        print("   ", json.dumps(r))
    for c in res.get("curves", []):
        print(f"    N={c['N']} store={c['store']}")
        for p in c["points"]:
            print("       ", json.dumps(p))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    results = {}
    failures = 0
    for modname in MODULES:
        short = modname.split(".")[-1]
        if args.only and args.only not in short:
            continue
        print(f"\n=== {short} ===", flush=True)
        t0 = time.time()
        try:
            mod = importlib.import_module(modname)
            res = mod.run()
            res["seconds"] = round(time.time() - t0, 1)
            results[short] = res
            _print_rows(res)
            print(f"    ({res['seconds']}s)", flush=True)
        except Exception:
            failures += 1
            traceback.print_exc()
            results[short] = {"error": traceback.format_exc()[-1500:]}
    out = os.path.join(os.path.dirname(__file__), "results.json")
    with open(out, "w") as f:
        json.dump(results, f, indent=1)
    print(f"\nwrote {out}; failures={failures}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
