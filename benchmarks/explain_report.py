"""Offline recall-gap decomposition from per-query explain telemetry.

Replays the bench query set through a serving engine with an
ExplainLogger at sample rate 1.0, then joins every explain record
against the synthetic relevance labels to answer "WHY did each missed
query miss?" — the question recall@k alone cannot. For every query
whose relevant doc is absent from the final top-k, the record pins the
stage that dropped it:

  candidate_miss   the relevant doc's cluster never entered the Stage-I
                   candidate list (seed + graph expansion) — selector
                   never saw it
  selector_miss    the cluster was a candidate but its LSTM probability
                   fell below theta — the selector said no
  budget_cutoff    probability cleared theta but the max_selected budget
                   cut it — more budget would have scored it
  ranked_out       the cluster WAS selected (or the doc arrived via the
                   sparse fusion side) yet the doc ranked below k_final —
                   a scoring/fusion limitation, not a selection one

covered + the four miss buckets partition the query set exactly; the
run asserts the miss fractions sum to the recall gap (1 - recall), so
the decomposition cannot silently leak queries. The output JSON reports
each bucket's count and fraction-of-gap.

Usage (index built by repro.launch.build_index with a trained selector):
  PYTHONPATH=src python -m benchmarks.explain_report --index-dir /tmp/idx \
      [--queries 64] [--batch 16] [--out report.json] [--query-seed 9]

Record schema + interpretation guide: docs/OBSERVABILITY.md.
"""

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from benchmarks import common as C


def decompose(records, ids, rel_doc, doc_cluster):
    """Per-query miss attribution. `records` must be qid-aligned with the
    query order (sample rate 1.0 on a fresh engine makes qid == row)."""
    by_qid = {r["qid"]: r for r in records}
    buckets = {"covered": 0, "candidate_miss": 0, "selector_miss": 0,
               "budget_cutoff": 0, "ranked_out": 0}
    rows = []
    for i in range(len(rel_doc)):
        rel = int(rel_doc[i])
        if rel in set(int(x) for x in ids[i]):
            buckets["covered"] += 1
            continue
        rec = by_qid.get(i)
        if rec is None:
            raise AssertionError(f"no explain record for qid {i} — "
                                 f"sample rate must be 1.0")
        c = int(doc_cluster[rel])
        cand = [int(x) for x in rec["cand"]]
        if c not in cand:
            kind = "candidate_miss"
            detail = {"rel_cluster": c}
        elif c in set(int(x) for x in rec["selected"]):
            kind = "ranked_out"
            detail = {"rel_cluster": c}
        else:
            p = float(rec["probs"][cand.index(c)])
            if p < float(rec["theta"]):
                kind = "selector_miss"
            else:
                kind = "budget_cutoff"
            detail = {"rel_cluster": c, "prob": round(p, 4),
                      "theta": rec["theta"],
                      "provenance": rec["provenance"][cand.index(c)]}
        buckets[kind] += 1
        rows.append({"qid": i, "kind": kind, **detail})
    return buckets, rows


def main():
    ap = argparse.ArgumentParser(
        description="Decompose the recall gap from explain telemetry.",
        epilog=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--index-dir", required=True,
                    help="built index with a trained selector")
    ap.add_argument("--queries", type=int, default=64)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--query-seed", type=int, default=9,
                    help="synth_queries seed (9 = the serve/bench set)")
    ap.add_argument("--out", default=None,
                    help="also write the report JSON here")
    args = ap.parse_args()

    from repro import index as index_lib
    from repro.data import synth_corpus, synth_queries
    from repro.obs import ExplainLogger

    reader = index_lib.IndexReader.open(args.index_dir, verify="size")
    meta = reader.manifest.get("extra", {}).get("corpus")
    if meta is None or meta.get("kind") != "synthetic":
        raise SystemExit("index lacks synthetic-corpus metadata; the "
                         "report regenerates queries from the manifest")
    corpus = synth_corpus(meta["seed"], meta["n_docs"], meta["dim"],
                          meta["vocab"])
    q = synth_queries(args.query_seed, corpus, args.queries)

    explain = ExplainLogger(sample_rate=1.0, capacity=args.queries)
    with reader.engine(max_batch=args.batch, explain=explain) as engine:
        all_ids = []
        for i in range(0, args.queries, args.batch):
            ids, _ = engine.retrieve(q.q_dense[i:i + args.batch],
                                     q.q_terms[i:i + args.batch],
                                     q.q_weights[i:i + args.batch])
            all_ids.append(np.asarray(ids))
        ids = np.concatenate(all_ids)
        doc_cluster = np.asarray(engine.index.doc_cluster)
        cfg = engine.cfg

    buckets, rows = decompose(explain.recent(), ids, q.rel_doc[:len(ids)],
                              doc_cluster)
    n = len(ids)
    assert sum(buckets.values()) == n, (buckets, n)
    recall = buckets["covered"] / n
    gap = 1.0 - recall
    miss_frac = {k: v / n for k, v in buckets.items() if k != "covered"}
    # the decomposition must PARTITION the gap — no leaked queries
    assert abs(sum(miss_frac.values()) - gap) < 1e-9, (miss_frac, gap)

    report = {
        **C.bench_meta(cfg),
        "n_queries": n,
        "k_final": int(cfg.k_final),
        "theta": float(cfg.theta),
        "budget": int(cfg.max_selected),
        "recall_at_k": round(recall, 4),
        "gap": round(gap, 4),
        "buckets": buckets,
        "gap_fractions": {k: round(v, 4) for k, v in miss_frac.items()},
        "explain_stats": explain.stats(),
        "misses": rows[:50],
    }
    text = json.dumps(report, indent=1)
    print(text)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
