"""Paper Table 8: design options. Stage-I ordering (SortByDist vs
SortByOverlap), Stage-II selector (pointwise-MLP ~ XGBoost, RNN, LSTM), and
LSTM feature-group ablations, all at matched average-#selected (3 and 5)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro.core import clusd as cl
from repro.core import train_lstm as tl
from repro.core.lstm import SELECTORS
from repro.data import mrr_at, recall_at


def _eval_at_targets(cfg, index, qs, params, selector, stage1, feat_mask,
                     targets=(3, 5)):
    """Evaluate retrieval quality with theta tuned to select ~target."""
    out = {}
    # features for tuning theta on the test queries
    from repro.core import sparse as sp
    sid, ss = sp.sparse_retrieve_topk(index.sparse_index, qs.q_terms,
                                      qs.q_weights, cfg.k_sparse)
    sel = cl.select_clusters(cfg, index, qs.q_dense, sid, ss,
                             selector_params=None, stage1=stage1)
    feats = np.asarray(sel["feats"]) * feat_mask
    _, apply = SELECTORS[selector]
    probs = np.asarray(apply(params, jnp.asarray(feats)))
    for tgt in targets:
        lo, hi = 0.0, 1.0
        for _ in range(30):
            mid = (lo + hi) / 2
            if (probs >= mid).sum(1).mean() > tgt:
                lo = mid
            else:
                hi = mid
        theta = (lo + hi) / 2
        cfg_t = dataclasses.replace(cfg, theta=float(theta),
                                    max_selected=max(targets) * 4)

        def retr(qd, qt, qw):
            sid2, ss2 = sp.sparse_retrieve_topk(index.sparse_index, qt, qw,
                                                cfg.k_sparse)
            sel2 = cl.select_clusters(cfg_t, index, qd, sid2, ss2,
                                      selector_params=None, stage1=stage1)
            f2 = sel2["feats"] * jnp.asarray(feat_mask)
            p2 = apply(params, f2)
            picked = p2 >= cfg_t.theta
            masked = jnp.where(picked, p2, -1.0)
            tp, ti = jax.lax.top_k(masked, cfg_t.max_selected)
            m = tp >= 0.0
            si = jnp.take_along_axis(sel2["cand"], ti, axis=1)
            did, ds, dm = cl.score_selected(index, qd, si, m)
            from repro.core import fusion
            return fusion.fuse_topk(sid2, ss2, did, jnp.where(dm, ds, 0.0),
                                    dm, index.n_docs, cfg.alpha, 100)

        ids, _ = jax.jit(retr)(qs.q_dense, qs.q_terms, qs.q_weights)
        out[tgt] = {"MRR@10": round(mrr_at(np.asarray(ids), qs.rel_doc), 4),
                    "R@100": round(recall_at(np.asarray(ids), qs.rel_doc,
                                             100), 4)}
    return out


def _stage1_only(cfg, index, qs, stage1, targets=(3, 5)):
    from repro.core import sparse as sp
    from repro.core import fusion
    out = {}
    for tgt in targets:
        def retr(qd, qt, qw):
            sid, ss = sp.sparse_retrieve_topk(index.sparse_index, qt, qw,
                                              cfg.k_sparse)
            sel = cl.select_clusters(cfg, index, qd, sid, ss,
                                     selector_params=None, stage1=stage1)
            si = sel["cand"][:, :tgt]
            m = jnp.ones_like(si, bool)
            did, ds, dm = cl.score_selected(index, qd, si, m)
            return fusion.fuse_topk(sid, ss, did, jnp.where(dm, ds, 0.0), dm,
                                    index.n_docs, cfg.alpha, 100)
        ids, _ = jax.jit(retr)(qs.q_dense, qs.q_terms, qs.q_weights)
        out[tgt] = {"MRR@10": round(mrr_at(np.asarray(ids), qs.rel_doc), 4),
                    "R@100": round(recall_at(np.asarray(ids), qs.rel_doc,
                                             100), 4)}
    return out


def run():
    cfg, corpus, index, _, (feats, labels), _ = C.trained_index()
    qs = C.test_queries(corpus, n=192)
    F = feats.shape[-1]
    rows = []

    # ---- stage 1 only ----
    for stage1 in ("dist", "overlap"):
        r = _stage1_only(cfg, index, qs, stage1)
        rows.append({"option": f"StageI={'SortByDist' if stage1=='dist' else 'SortByOverlap'} (no StageII)",
                     **{f"@{t}": v for t, v in r.items()}})

    # ---- stage 2 model options (paper: stage1 = SortByDist here; train
    # the selectors on SortByDist candidate sequences to match) ----
    from repro.data import synth_queries
    train_q = synth_queries(1, corpus, cfg.train_queries)
    _, feats_d, labels_d = tl.make_labels(cfg, index, train_q.q_dense,
                                          train_q.q_terms, train_q.q_weights,
                                          stage1="dist")
    feats_d, labels_d = np.asarray(feats_d), np.asarray(labels_d)
    ones = np.ones((1, 1, F), np.float32)
    for sel_name, tag in [("mlp", "pointwise-MLP (XGBoost-like)"),
                          ("rnn", "RNN"), ("lstm", "LSTM")]:
        params, _ = tl.train_selector(cfg, jax.random.key(4), feats_d,
                                      labels_d, selector=sel_name, lr=5e-3)
        r = _eval_at_targets(cfg, index, qs, params, sel_name, "dist", ones)
        rows.append({"option": f"StageII={tag}",
                     **{f"@{t}": v for t, v in r.items()}})

    # ---- feature-group ablations (stage1 = SortByOverlap, LSTM) ----
    u, v = cfg.u_bins, cfg.v_bins
    masks = {
        "w/o inter-cluster dist": np.concatenate(
            [np.ones(1), np.zeros(u), np.ones(2 * v)]).astype(np.float32),
        "w/o S-C overlap": np.concatenate(
            [np.ones(1 + u), np.zeros(2 * v)]).astype(np.float32),
        "default (all features)": np.ones(F, np.float32),
    }
    for tag, mask in masks.items():
        m = mask[None, None, :]
        params, _ = tl.train_selector(cfg, jax.random.key(5), feats * m,
                                      labels, selector="lstm", lr=5e-3)
        r = _eval_at_targets(cfg, index, qs, params, "lstm", "overlap", m)
        rows.append({"option": f"LSTM {tag}",
                     **{f"@{t}": v_ for t, v_ in r.items()}})
    return {"table": "table8_ablation", "rows": rows}
