"""Roofline report: reads the dry-run artifacts and prints the per-cell
three-term table (EXPERIMENTS.md §Roofline is generated from this)."""

import glob
import json
import os

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def load_cells(mesh="single", tag=""):
    rows = []
    suffix = f"__{mesh}{('_' + tag) if tag else ''}.json"
    for f in sorted(glob.glob(os.path.join(ART, f"*{suffix}"))):
        d = json.load(open(f))
        rows.append(d)
    return rows


def run():
    rows = []
    for d in load_cells("single"):
        if d.get("status") == "skip":
            rows.append({"cell": f"{d['arch']} x {d['shape']}",
                         "status": "SKIP", "note": d["reason"][:60]})
            continue
        if d.get("status") != "ok":
            rows.append({"cell": f"{d['arch']} x {d['shape']}",
                         "status": "FAIL"})
            continue
        r = d["roofline"]
        rows.append({
            "cell": f"{d['arch']} x {d['shape']}",
            "status": "ok",
            "peak_gb": round(d["memory"]["peak_gb"], 1),
            "compute_s": f"{r['compute_s']:.2e}",
            "memory_s": f"{r['memory_s']:.2e}",
            "collective_s": f"{r['collective_s']:.2e}",
            "dominant": r["dominant"],
            "MODEL/HLO": round(d["useful_flops_ratio"], 3),
            "MFU_bound": round(r["mfu_upper_bound"], 3),
        })
    return {"table": "roofline", "rows": rows}
