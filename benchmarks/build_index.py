"""Index build/serve-split benchmark: offline build cost vs cold-open cost,
for both on-disk formats.

Measures what the persistent index subsystem buys at serve time — the seed
rebuilt clusters + packed blocks in memory on every process start; a built
index opens in milliseconds (manifest + mmap) and answers its first query
without ever materializing the embedding matrix. The v2 (PQ code shard)
build additionally runs off an np.memmap staging of the corpus — the
corpus>RAM path — and its size/quality are compared against v1.

Writes BENCH_index.json at the repo root (stamped with git SHA + config so
the trajectory is comparable across PRs):
  build_wall_s                  offline pipeline + pack + checksum time (v1)
  index_bytes / n_block_shards  v1 on-disk footprint
  cold_open_ms                  manifest validate + mmap + store construction
  cold_open_to_first_query_ms   ... + engine + first batch (incl. jit)
  steady_batch_ms               second batch on the warm engine
  io                            block I/O ops/bytes for the serve phase
  max_format_version            newest format this repo writes (regression
                                gate: size growth needs a version bump)
  pq                            the v2 build: index_bytes, size_ratio_vs_v1
                                (acceptance: >= 4x), MRR@10 + delta vs the
                                float32 serve, code-byte I/O
  update                        incremental delta (5% upserts + 2% deletes,
                                shard-localized) applied to the v1 index:
                                delta wall time vs a timed full rebuild of
                                the same logical corpus, shard bytes
                                rewritten vs total, hot-reload serving
                                check, and top-k parity vs a compacted
                                (from-scratch serialized) copy.
                                Acceptance: < 30% of shard bytes rewritten,
                                < 25% of full-rebuild wall, exact v1 parity.

Standalone: PYTHONPATH=src python -m benchmarks.build_index
            [--no-bench-update]
"""

import dataclasses
import json
import math
import os
import tempfile
import time

import jax
import numpy as np

from benchmarks import common as C
from repro import index as index_lib
from repro.core import train_lstm as tl
from repro.data import mrr_at, synth_corpus, synth_queries

N_DOCS = 20_000          # matches BENCH_serve.json's corpus size
N_SHARDS = 8
N_QUERIES = 64
BATCH = 32
PQ_NSUB = 12             # 48-dim corpus -> 4-dim subspaces, 16x block shrink
PQ_ROTATE = True         # OPQ-lite rotation: measured MRR delta ~0.004


UPSERT_FRAC = 0.05       # acceptance: this delta rewrites < 30% of shard
DELETE_FRAC = 0.02       # bytes in < 25% of the full-rebuild wall time


def bench_update(out_dir, cfg, corpus, qs):
    """Apply a localized 5% upsert + 2% delete delta to the built v1 index;
    measure delta wall vs a timed full rebuild of the same logical corpus
    (k-means + pack, no LSTM — the delta does not retrain either), bytes
    rewritten vs total shard bytes, hot-reload serving, and top-k parity
    vs a compacted (= from-scratch serialized) copy of the result."""
    import shutil

    from repro.launch.update_index import synth_delta

    n_up = int(round(UPSERT_FRAC * cfg.n_docs))
    n_del = int(round(DELETE_FRAC * cfg.n_docs))
    reader = index_lib.IndexReader.open(out_dir)
    delta, info = synth_delta(reader, n_up, n_del, seed=5)

    # one engine serves across the commit: queries before, hot-swap,
    # after — every retrieve that raises counts as a failed request
    engine = reader.engine(max_batch=BATCH, cache_capacity=cfg.n_clusters)
    failed_requests = 0

    def _serve_batch(lo):
        nonlocal failed_requests
        try:
            ids, _ = engine.retrieve(qs.q_dense[lo:lo + BATCH],
                                     qs.q_terms[lo:lo + BATCH],
                                     qs.q_weights[lo:lo + BATCH])
            return np.asarray(ids)
        except Exception:
            failed_requests += BATCH
            return None

    pre_ids = _serve_batch(0)
    t0 = time.perf_counter()
    report = index_lib.write_index_delta(out_dir, delta)
    delta_wall_s = time.perf_counter() - t0
    engine.reload_index()
    post_ids = _serve_batch(0)
    est = engine.stats()
    engine.close()
    assert failed_requests == 0, \
        f"{failed_requests} requests failed across the hot reload"
    assert pre_ids.shape == post_ids.shape
    assert est["reloads"] == 1 and est["cache"]["size"] >= 0

    # full-rebuild baseline on the SAME logical corpus (append new docs,
    # overwrite replaced rows, blank deleted docs' terms), timed like the
    # delta: clustering + packing, no selector training on either side
    emb0 = np.asarray(corpus.embeddings, np.float32)
    n_app = int((delta.upsert_ids >= cfg.n_docs).sum())
    emb_new = np.concatenate(
        [emb0, np.zeros((n_app, emb0.shape[1]), np.float32)])
    emb_new[delta.upsert_ids] = delta.upsert_embeddings
    dt = np.concatenate([np.asarray(corpus.doc_terms),
                         np.full((n_app,) + corpus.doc_terms.shape[1:], -1,
                                 np.int32)])
    dw = np.concatenate([np.asarray(corpus.doc_weights),
                         np.zeros((n_app,) + corpus.doc_weights.shape[1:],
                                  np.float32)])
    dt[delta.upsert_ids] = delta.upsert_terms
    dw[delta.upsert_ids] = delta.upsert_weights
    dt[delta.delete_ids] = -1
    dw[delta.delete_ids] = 0.0
    rcfg = dataclasses.replace(cfg, n_docs=int(emb_new.shape[0]))
    rebuild_dir = out_dir + "_rebuild"
    t1 = time.perf_counter()
    ridx = index_lib.build_index_offline(
        rcfg, jax.random.key(0), emb_new, dt, dw,
        shard_docs=math.ceil(rcfg.n_docs / N_SHARDS))
    index_lib.write_index(rebuild_dir, rcfg, ridx, emb_new,
                          n_shards=N_SHARDS)
    rebuild_wall_s = time.perf_counter() - t1

    # parity: the updated index vs its compaction (by the update-subsystem
    # invariant, compaction == from-scratch serialization of this state)
    comp_dir = out_dir + "_compacted"
    if os.path.exists(comp_dir):
        shutil.rmtree(comp_dir)
    shutil.copytree(out_dir, comp_dir)
    index_lib.compact_index(comp_dir)
    nq = 2 * BATCH
    with index_lib.IndexReader.open(out_dir).engine(max_batch=BATCH) as e1:
        live_ids, _ = e1.retrieve(qs.q_dense[:nq], qs.q_terms[:nq],
                                  qs.q_weights[:nq])
    with index_lib.IndexReader.open(comp_dir).engine(max_batch=BATCH) as e2:
        comp_ids, _ = e2.retrieve(qs.q_dense[:nq], qs.q_terms[:nq],
                                  qs.q_weights[:nq])
    exact = bool(np.array_equal(np.asarray(live_ids), np.asarray(comp_ids)))
    mrr_live = round(mrr_at(np.asarray(live_ids), qs.rel_doc[:nq]), 4)
    mrr_comp = round(mrr_at(np.asarray(comp_ids), qs.rel_doc[:nq]), 4)

    bytes_frac = report["bytes_rewritten_frac"]
    wall_ratio = delta_wall_s / rebuild_wall_s
    assert exact, ("updated index diverged from its compacted "
                   "(from-scratch serialized) copy")
    assert bytes_frac < 0.30, \
        f"delta rewrote {bytes_frac:.0%} of shard bytes (need < 30%)"
    assert wall_ratio < 0.25, \
        f"delta took {wall_ratio:.0%} of full-rebuild wall (need < 25%)"
    return {
        "upsert_frac": UPSERT_FRAC,
        "delete_frac": DELETE_FRAC,
        "n_upserts": report["n_upserts"],
        "n_deletes": report["n_deletes"],
        "n_replaced": report["n_replaced"],
        "n_appended": report["n_appended"],
        "target_shards": info["target_shards"],
        "generation": report["generation"],
        "wall_s": round(delta_wall_s, 3),
        "full_rebuild_wall_s": round(rebuild_wall_s, 3),
        "wall_ratio": round(wall_ratio, 4),
        "bytes_rewritten": report["bytes_rewritten"],
        "shard_bytes_total": report["shard_bytes_total"],
        "bytes_rewritten_frac": bytes_frac,
        "shards_rewritten": report["shards_rewritten"],
        "n_shards": report["n_shards"],
        "reclustered_shards": report["reclustered_shards"],
        "reload": {"reloads": est["reloads"],
                   "cache_clears": est["cache"]["clears"],
                   "failed_requests": failed_requests},
        "parity": {"exact": exact, "MRR@10_updated": mrr_live,
                   "MRR@10_compacted": mrr_comp},
    }


def run(bench_update_row=True):
    cfg = dataclasses.replace(C.bench_cfg(), n_docs=N_DOCS,
                              train_queries=256, epochs=15)
    corpus = synth_corpus(0, cfg.n_docs, cfg.dim, cfg.vocab, topic_noise=0.5)
    emb = np.asarray(corpus.embeddings)
    tmp = tempfile.mkdtemp()
    out_dir = os.path.join(tmp, "index")

    # ---- offline build -------------------------------------------------
    t0 = time.perf_counter()
    index = index_lib.build_index_offline(
        cfg, jax.random.key(0), emb, corpus.doc_terms, corpus.doc_weights,
        shard_docs=math.ceil(cfg.n_docs / N_SHARDS))
    index.embeddings = corpus.embeddings        # offline-only: label gen
    tq = synth_queries(1, corpus, cfg.train_queries)
    _, feats, labels = tl.make_labels(cfg, index, tq.q_dense, tq.q_terms,
                                      tq.q_weights)
    index.lstm_params, _ = tl.train_selector(cfg, jax.random.key(2),
                                             np.asarray(feats),
                                             np.asarray(labels))
    index.embeddings = None
    manifest = index_lib.write_index(out_dir, cfg, index, emb,
                                     n_shards=N_SHARDS)
    build_wall_s = time.perf_counter() - t0

    # ---- cold open -> first query --------------------------------------
    qs = synth_queries(9, corpus, N_QUERIES, dense_noise=0.30,
                       term_noise_frac=0.4)
    t1 = time.perf_counter()
    reader = index_lib.IndexReader.open(out_dir, verify="size")
    lcfg, lindex = reader.load_index()
    engine = reader.engine(cfg=lcfg, index=lindex, max_batch=BATCH,
                           cache_capacity=cfg.n_clusters)
    open_ms = (time.perf_counter() - t1) * 1e3
    ids1, _ = engine.retrieve(qs.q_dense[:BATCH], qs.q_terms[:BATCH],
                              qs.q_weights[:BATCH])
    first_query_ms = (time.perf_counter() - t1) * 1e3
    t2 = time.perf_counter()
    ids2, _ = engine.retrieve(qs.q_dense[BATCH:2 * BATCH],
                              qs.q_terms[BATCH:2 * BATCH],
                              qs.q_weights[BATCH:2 * BATCH])
    steady_batch_ms = (time.perf_counter() - t2) * 1e3
    engine.close()
    st = engine.stats()
    ids = np.concatenate([np.asarray(ids1), np.asarray(ids2)])
    mrr_v1 = round(mrr_at(ids, qs.rel_doc[:2 * BATCH]), 4)

    # ---- v2 PQ build from an np.memmap source (corpus > RAM path) ------
    staged = os.path.join(tmp, "embeddings.bin")
    emb.astype(np.float32).tofile(staged)
    emb_mm = np.memmap(staged, dtype=np.float32, mode="r", shape=emb.shape)
    t3 = time.perf_counter()
    from repro.core import quant as quant_lib
    index.quantizer = quant_lib.train_pq_stream(
        jax.random.key(3), emb_mm, PQ_NSUB, rotate=PQ_ROTATE,
        chunk_docs=4096)
    pq_dir = os.path.join(tmp, "index_pq")
    manifest_pq = index_lib.write_index(
        pq_dir, cfg, index, emb_mm, n_shards=N_SHARDS,
        format_version=index_lib.FORMAT_VERSION_PQ, chunk_docs=4096)
    pq_build_s = time.perf_counter() - t3
    reader_pq = index_lib.IndexReader.open(pq_dir, verify="size")
    with reader_pq.engine(max_batch=BATCH,
                          cache_capacity=cfg.n_clusters) as eng_pq:
        ids_pq = []
        for lo in range(0, 2 * BATCH, BATCH):
            out_pq, _ = eng_pq.retrieve(qs.q_dense[lo:lo + BATCH],
                                        qs.q_terms[lo:lo + BATCH],
                                        qs.q_weights[lo:lo + BATCH])
            ids_pq.append(np.asarray(out_pq))
    st_pq = eng_pq.stats()
    mrr_pq = round(mrr_at(np.concatenate(ids_pq), qs.rel_doc[:2 * BATCH]), 4)
    size_ratio = manifest["total_bytes"] / manifest_pq["total_bytes"]
    assert size_ratio >= 4.0, \
        f"v2 PQ index only {size_ratio:.1f}x smaller than v1 (need >= 4x)"
    assert abs(mrr_pq - mrr_v1) <= 0.02, \
        f"v2 MRR@10 {mrr_pq} vs v1 {mrr_v1}: outside 0.02 tolerance"

    # ---- incremental update: delta vs full rebuild (--bench-update) ----
    update_row = None
    if bench_update_row:
        update_row = bench_update(out_dir, cfg, corpus, qs)

    result = {
        "bench": "build_index", **C.bench_meta(cfg),
        "n_shards": N_SHARDS,
        "build_wall_s": round(build_wall_s, 2),
        "index_bytes": manifest["total_bytes"],
        "index_mb": round(manifest["total_bytes"] / 2**20, 2),
        "n_block_shards": len(manifest["block_shards"]),
        "cold_open_ms": round(open_ms, 1),
        "cold_open_to_first_query_ms": round(first_query_ms, 1),
        "steady_batch_ms": round(steady_batch_ms, 1),
        "MRR@10": mrr_v1,
        "io": st.get("io", {}),
        "cluster_fill": manifest["stats"]["cluster_fill"],
        "max_format_version": index_lib.FORMAT_VERSION_PQ,
        "pq": {
            "format_version": manifest_pq["format_version"],
            "nsub": PQ_NSUB,
            "build_wall_s": round(pq_build_s, 2),
            "index_bytes": manifest_pq["total_bytes"],
            "index_mb": round(manifest_pq["total_bytes"] / 2**20, 2),
            "size_ratio_vs_v1": round(size_ratio, 2),
            "MRR@10": mrr_pq,
            "mrr_delta_vs_float32": round(abs(mrr_pq - mrr_v1), 4),
            "memmap_source": True,
            "io": st_pq.get("io", {}),
        },
    }
    if update_row is not None:
        result["update"] = update_row
    out = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "BENCH_index.json"))
    with open(out, "w") as f:
        json.dump(result, f, indent=1)
    print(f"wrote {out}")
    return result


if __name__ == "__main__":
    import argparse
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--bench-update", dest="bench_update",
                    action="store_true", default=True,
                    help="measure the incremental-delta 'update' row "
                         "(default on)")
    ap.add_argument("--no-bench-update", dest="bench_update",
                    action="store_false",
                    help="skip the update row (faster local runs)")
    args = ap.parse_args()
    res = run(bench_update_row=args.bench_update)
    print(json.dumps({k: v for k, v in res.items() if k != "cluster_fill"},
                     indent=1))
