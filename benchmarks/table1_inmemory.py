"""Paper Table 1: cluster-based in-memory selective retrieval, with and
without quantization. Baselines: full fusion (oracle), IVF top-p%, CDFS,
sparse-only, dense-only."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro.core import baselines as bl
from repro.core import clusd as cl
from repro.core import quant as qt
from repro.core import sparse as sparse_lib
from repro.data import mrr_at, recall_at


def run():
    cfg, corpus, index, params, (feats, labels), hist = C.trained_index()
    index.lstm_params = params
    qs = C.test_queries(corpus)
    rows = []

    def add(name, ids, lat, pct_d):
        rows.append({"method": name, "%D": round(pct_d, 4),
                     **C.quality(ids, qs), "latency_ms": round(lat, 1)})

    # dense only / sparse only / oracle fusion
    (ids, _), lat = C.timed(
        jax.jit(lambda q: cl.full_dense_topk(index.embeddings, q, 100)),
        qs.q_dense)
    add("D (full dense)", ids, lat, 100.0)
    (sid, ss), lat = C.timed(
        jax.jit(lambda t, w: sparse_lib.sparse_retrieve_topk(
            index.sparse_index, t, w, cfg.k_sparse)),
        qs.q_terms, qs.q_weights)
    add("S (sparse)", sid, lat, 0.0)
    oracle = dataclasses.replace(cfg, theta=-1.0,
                                 max_selected=cfg.n_candidates)
    (ids, _, diag), lat = C.timed(
        jax.jit(lambda qd, qt_, qw: cl.retrieve(oracle, index, qd, qt_, qw,
                                                selector_params=params)),
        qs.q_dense, qs.q_terms, qs.q_weights)
    add("S + D-top32cl (upper bound)", ids, lat,
        100 * float(diag["frac_docs_scanned"].mean()))

    # IVF p%
    for pct in (10, 5, 2):
        n_probe = max(1, int(cfg.n_clusters * pct / 100))
        (ids, _, _), lat = C.timed(
            jax.jit(lambda qd, qt_, qw: bl.ivf_retrieve(
                cfg, index, qd, qt_, qw, n_probe)),
            qs.q_dense, qs.q_terms, qs.q_weights)
        add(f"S + D-IVF {pct}%", ids, lat, pct)

    # CDFS
    (ids, _, d), lat = C.timed(
        jax.jit(lambda qd, qt_, qw: bl.cdfs_retrieve(cfg, index, qd, qt_, qw)),
        qs.q_dense, qs.q_terms, qs.q_weights)
    cap_frac = cfg.cluster_cap / index.n_docs
    add("S + CDFS", ids, lat, 100 * float(d["n_selected"].mean()) * cap_frac)

    # CluSD
    (ids, _, diag), lat = C.timed(
        jax.jit(lambda qd, qt_, qw: cl.retrieve(cfg, index, qd, qt_, qw,
                                                selector_params=params)),
        qs.q_dense, qs.q_terms, qs.q_weights)
    add("S + CluSD", ids, lat, 100 * float(diag["frac_docs_scanned"].mean()))
    avg_sel = float(diag["n_selected"].mean())

    # quantized section (OPQ analogue)
    pq = qt.train_pq(jax.random.key(3), corpus.embeddings, nsub=8, iters=6)
    index.quantizer = pq
    (ids, _, diag), lat = C.timed(
        jax.jit(lambda qd, qt_, qw: cl.retrieve(cfg, index, qd, qt_, qw,
                                                selector_params=params)),
        qs.q_dense, qs.q_terms, qs.q_weights)
    add("S + CluSD (PQ m=8)", ids, lat,
        100 * float(diag["frac_docs_scanned"].mean()))
    index.quantizer = None

    return {"table": "table1_inmemory", "avg_clusters_selected": avg_sel,
            "lstm_loss": [round(hist[0], 4), round(hist[-1], 4)],
            "rows": rows}
