"""Paper Table 2: CluSD vs proximity-graph navigation (LADR-like) under a
matched compute budget, including the extra index-space accounting that is
CluSD's headline advantage."""

import jax

from benchmarks import common as C
from repro.core import baselines as bl
from repro.core import clusd as cl


def run():
    cfg, corpus, index, params, _, _ = C.trained_index()
    index.lstm_params = params
    qs = C.test_queries(corpus)
    D, dim = index.embeddings.shape
    rows = []

    knn = bl.build_doc_knn(index, n_neighbors=8, probe_clusters=3)
    for name, kw in [("S + LADR(default)", dict(n_seeds=64, depth=3,
                                                budget=512)),
                     ("S + LADR(fast)", dict(n_seeds=16, depth=2,
                                             budget=256))]:
        (ids, _, d), lat = C.timed(
            jax.jit(lambda qd, qt, qw: bl.ladr_retrieve(
                cfg, index, knn, qd, qt, qw, **kw)),
            qs.q_dense, qs.q_terms, qs.q_weights)
        rows.append({"method": name, **C.quality(ids, qs),
                     "latency_ms": round(lat, 1),
                     "extra_space_mb": round(D * knn.shape[1] * 4 / 2**20, 2)})

    (ids, _, diag), lat = C.timed(
        jax.jit(lambda qd, qt, qw: cl.retrieve(cfg, index, qd, qt, qw,
                                               selector_params=params)),
        qs.q_dense, qs.q_terms, qs.q_weights)
    clusd_space = (index.neighbor_ids.size * 8
                   + index.centroids.size * 4) / 2**20
    rows.append({"method": "S + CluSD", **C.quality(ids, qs),
                 "latency_ms": round(lat, 1),
                 "extra_space_mb": round(float(clusd_space), 2)})
    return {"table": "table2_graphnav", "rows": rows}
